//! Benchmark harness + workload generators regenerating the paper's
//! Chapter-8 evaluation (experiment index in DESIGN.md §5).
//!
//! `cargo bench` (rust/benches/paper.rs) and `examples/bench_tables.rs`
//! both drive these functions; they print rows shaped like the paper's
//! tables (aggregate MB/s per client/server combination, etc.). Absolute
//! numbers come from the [`SimCost`] disk model — 1998 disks scaled
//! 10x — so *shapes* (who wins, scaling, crossovers) are the result.

// Bench harness: measuring wall-clock time is the entire job.
#![allow(clippy::disallowed_methods)]

use std::sync::{Arc, Barrier};
use std::time::Instant;

use anyhow::Result;

use crate::access::AccessDesc;
use crate::baselines::{two_phase_read, HostCentralized, RomioLike, UnixSeq};
use crate::client::Client;
use crate::disk::{Disk, SimCost, SimDisk};
use crate::hints::{FileAdminHint, Hint};
use crate::layout::Distribution;
use crate::memory::CacheConfig;
use crate::modes::ServerPool;
use crate::msg::OpenMode;
use crate::server::{DiskKind, ServerConfig};
use crate::util::mbps;
use crate::vimpios::{get_view_pattern, Amode, Basic, ClientGroup, Datatype, MpiFile};

// ------------------------------------------------------------- reporting

/// Machine-readable results (`vipios bench --json`): every
/// [`print_table`] call is also recorded here, and the CLI serialises
/// the collected tables to `BENCH_<exp>.json` — the perf-trajectory
/// artifact the human-readable tables could not provide.
pub mod report {
    use std::sync::Mutex;

    /// One recorded result table.
    #[derive(Debug, Clone)]
    pub struct Table {
        pub title: String,
        pub headers: Vec<String>,
        pub rows: Vec<Vec<String>>,
    }

    static TABLES: Mutex<Vec<Table>> = Mutex::new(Vec::new());

    /// Clear the collector (call before a bench run).
    pub fn reset() {
        TABLES.lock().unwrap().clear();
    }

    pub(super) fn record(title: &str, headers: &[&str], rows: &[Vec<String>]) {
        TABLES.lock().unwrap().push(Table {
            title: title.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: rows.to_vec(),
        });
    }

    /// Tables recorded since the last [`reset`].
    pub fn tables() -> Vec<Table> {
        TABLES.lock().unwrap().clone()
    }

    fn esc(s: &str) -> String {
        let mut out = String::with_capacity(s.len());
        for ch in s.chars() {
            match ch {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }

    /// A cell that is a plain finite number is emitted as a JSON number
    /// (re-serialised through f64, so Rust-parseable-but-invalid-JSON
    /// spellings like `.5` or `+1` come out canonical), everything else
    /// as a string.
    fn cell(s: &str) -> String {
        let t = s.trim();
        let numeric = !t.is_empty()
            && t.chars().all(|c| c.is_ascii_digit() || "+-.eE".contains(c))
            && t.parse::<f64>().is_ok_and(|v| v.is_finite());
        match t.parse::<f64>() {
            Ok(v) if numeric => format!("{v}"),
            _ => format!("\"{}\"", esc(s)),
        }
    }

    /// Serialise the collected tables (hand-rolled: no serde in the
    /// vendored crate set).
    pub fn to_json(experiment: &str, quick: bool) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\"experiment\":\"{}\",\"quick\":{},\"tables\":[",
            esc(experiment),
            quick
        ));
        let tables = tables();
        for (ti, t) in tables.iter().enumerate() {
            if ti > 0 {
                out.push(',');
            }
            out.push_str(&format!("{{\"title\":\"{}\",\"headers\":[", esc(&t.title)));
            for (i, h) in t.headers.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!("\"{}\"", esc(h)));
            }
            out.push_str("],\"rows\":[");
            for (ri, row) in t.rows.iter().enumerate() {
                if ri > 0 {
                    out.push(',');
                }
                out.push('[');
                for (ci, c) in row.iter().enumerate() {
                    if ci > 0 {
                        out.push(',');
                    }
                    out.push_str(&cell(c));
                }
                out.push(']');
            }
            out.push_str("]}");
        }
        out.push_str("]}\n");
        out
    }

    /// Write `BENCH_<exp>.json`-style output to `path`.
    pub fn write_json(
        path: &std::path::Path,
        experiment: &str,
        quick: bool,
    ) -> std::io::Result<()> {
        std::fs::write(path, to_json(experiment, quick))
    }
}

/// Print a paper-style table (and record it for `--json`).
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    report::record(title, headers, rows);
    println!("\n== {title} ==");
    let widths: Vec<usize> = headers
        .iter()
        .enumerate()
        .map(|(i, h)| {
            rows.iter()
                .map(|r| r.get(i).map(|c| c.len()).unwrap_or(0))
                .chain(std::iter::once(h.len()))
                .max()
                .unwrap_or(0)
        })
        .collect();
    let line = |cells: Vec<String>| {
        let s: Vec<String> = cells
            .iter()
            .zip(&widths)
            .map(|(c, &w)| format!("{c:>w$}"))
            .collect();
        println!("  {}", s.join("  "));
    };
    line(headers.iter().map(|s| s.to_string()).collect());
    for r in rows {
        line(r.clone());
    }
}

// ------------------------------------------------------------- workloads

/// Default bench disk model + server config.
pub fn bench_server_config(cache_bytes: u64, overhead_us: u64) -> ServerConfig {
    ServerConfig {
        disks: 1,
        kind: DiskKind::Sim(SimCost::paper_1998()),
        cache: CacheConfig {
            page: 64 * 1024,
            capacity: cache_bytes,
            write_back: true,
        },
        prefetch: true,
        readahead: 256 * 1024,
        request_overhead: std::time::Duration::from_micros(overhead_us),
        queue_depth: 8,
        ..ServerConfig::default()
    }
}

/// Result of one ViPIOS shared-file run.
#[derive(Debug, Clone, Copy)]
pub struct BwResult {
    pub write_mbps: f64,
    pub read_mbps: f64,
}

/// E1/E2/E5 workload: `nclients` SPMD clients write disjoint BLOCK
/// regions of one shared file striped over `nservers`, then read them
/// back; aggregate bandwidth per phase. `overhead_us > 0` models
/// non-dedicated I/O nodes (CPU shared with compute, E2).
pub fn vipios_shared_file(
    nclients: usize,
    nservers: usize,
    total_bytes: u64,
    req_bytes: u64,
    cache_bytes: u64,
    overhead_us: u64,
) -> Result<BwResult> {
    let pool = ServerPool::start(nservers, bench_server_config(cache_bytes, overhead_us))?;
    // preparation phase: file-admin hint for the SPMD block distribution
    {
        let mut c = pool.client()?;
        c.hint(Hint::FileAdmin(FileAdminHint {
            name: "bench".into(),
            distribution: Distribution::block_for(total_bytes, nservers as u32),
            nprocs: Some(nclients as u32),
        }))?;
        c.disconnect()?;
    }
    let per = total_bytes / nclients as u64;
    let start = Arc::new(Barrier::new(nclients + 1));
    let mid = Arc::new(Barrier::new(nclients + 1));
    let end = Arc::new(Barrier::new(nclients + 1));
    let mut handles = Vec::new();
    for cidx in 0..nclients {
        let world = pool.world().clone();
        let (start, mid, end) = (start.clone(), mid.clone(), end.clone());
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut c = Client::connect(&world)?;
            let h = c.open("bench", OpenMode::rdwr_create())?;
            let base = cidx as u64 * per;
            let chunk = vec![0xA5u8; req_bytes as usize];
            start.wait();
            let mut off = base;
            while off < base + per {
                let n = req_bytes.min(base + per - off);
                c.write_at(h, off, &chunk[..n as usize])?;
                off += n;
            }
            // flush delayed writes so the write phase pays its disk cost
            c.sync(h)?;
            mid.wait();
            // read phase (after all writes land)
            let mut buf = vec![0u8; req_bytes as usize];
            let mut off = base;
            end.wait();
            while off < base + per {
                let n = req_bytes.min(base + per - off);
                c.read_at(h, off, &mut buf[..n as usize])?;
                off += n;
            }
            c.close(h)?;
            c.disconnect()?;
            Ok(())
        }));
    }
    start.wait();
    let t0 = Instant::now();
    mid.wait();
    let write_t = t0.elapsed();
    // cold-cache the read phase (the paper's read tests start with
    // nothing resident)
    {
        let mut admin = pool.client()?;
        for &s in pool.server_ranks() {
            admin.hint_to(s, Hint::System(crate::hints::SystemHint::DropCaches))?;
        }
        admin.disconnect()?;
    }
    let t1 = Instant::now();
    end.wait();
    for h in handles {
        h.join().unwrap()?;
    }
    let read_t = t1.elapsed();
    pool.shutdown()?;
    Ok(BwResult {
        write_mbps: mbps(total_bytes, write_t),
        read_mbps: mbps(total_bytes, read_t),
    })
}

/// E3 baseline: single sequential UNIX stream over one sim disk.
pub fn unix_seq_file(total_bytes: u64, req_bytes: u64) -> Result<BwResult> {
    let disk: Arc<dyn Disk> = Arc::new(SimDisk::new(SimCost::paper_1998()));
    let mut f = UnixSeq::new(disk);
    let chunk = vec![0xA5u8; req_bytes as usize];
    let t0 = Instant::now();
    let mut off = 0;
    while off < total_bytes {
        let n = req_bytes.min(total_bytes - off) as usize;
        f.write(&chunk[..n])?;
        off += n as u64;
    }
    let wt = t0.elapsed();
    f.seek(0);
    let mut buf = vec![0u8; req_bytes as usize];
    let t1 = Instant::now();
    let mut off = 0;
    while off < total_bytes {
        let n = req_bytes.min(total_bytes - off) as usize;
        f.read(&mut buf[..n])?;
        off += n as u64;
    }
    let rt = t1.elapsed();
    Ok(BwResult { write_mbps: mbps(total_bytes, wt), read_mbps: mbps(total_bytes, rt) })
}

/// E3 baseline: HPF host-node model — `nclients` node processes, all I/O
/// through one host on one disk.
pub fn host_centralized_file(
    nclients: usize,
    total_bytes: u64,
    req_bytes: u64,
) -> Result<BwResult> {
    let disk: Arc<dyn Disk> = Arc::new(SimDisk::new(SimCost::paper_1998()));
    let host = HostCentralized::start(disk);
    let per = total_bytes / nclients as u64;
    let run = |write: bool| -> std::time::Duration {
        let barrier = Arc::new(Barrier::new(nclients + 1));
        let done = Arc::new(Barrier::new(nclients + 1));
        let mut hs = Vec::new();
        for cidx in 0..nclients {
            let node = host.node();
            let (barrier, done) = (barrier.clone(), done.clone());
            hs.push(std::thread::spawn(move || {
                let base = cidx as u64 * per;
                barrier.wait();
                let mut off = base;
                while off < base + per {
                    let n = req_bytes.min(base + per - off);
                    if write {
                        node.write(off, vec![0xA5u8; n as usize]);
                    } else {
                        let _ = node.read(off, n);
                    }
                    off += n;
                }
                done.wait();
            }));
        }
        barrier.wait();
        let t0 = Instant::now();
        done.wait();
        for h in hs {
            h.join().unwrap();
        }
        t0.elapsed()
    };
    let wt = run(true);
    let rt = run(false);
    host.stop();
    Ok(BwResult { write_mbps: mbps(total_bytes, wt), read_mbps: mbps(total_bytes, rt) })
}

/// E4: strided access — ViMPIOS (server-side view resolution) vs the
/// ROMIO-like library (client-side data sieving). Pattern: every
/// `stride`-th `blk`-byte record of a `total_bytes` file, one client.
pub fn strided_vipios(
    nservers: usize,
    total_bytes: u64,
    blk: u32,
    stride: u32,
) -> Result<f64> {
    let pool = ServerPool::start(nservers, bench_server_config(2 << 20, 0))?;
    let mut c = pool.client()?;
    let h = c.open("strided", OpenMode::rdwr_create())?;
    // write contiguous base data first
    let chunk = vec![1u8; 1 << 20];
    let mut off = 0;
    while off < total_bytes {
        let n = (1u64 << 20).min(total_bytes - off);
        c.write_at(h, off, &chunk[..n as usize])?;
        off += n;
    }
    c.sync(h)?;
    for &s in pool.server_ranks() {
        c.hint_to(s, Hint::System(crate::hints::SystemHint::DropCaches))?;
    }
    // strided read through a view
    let dt = Datatype::vector(1, blk / 4, stride / 4, Datatype::Basic(Basic::Int));
    let desc = get_view_pattern(&dt);
    c.set_view(h, 0, desc)?;
    let logical_total = total_bytes / stride as u64 * blk as u64;
    let mut buf = vec![0u8; (1 << 20).min(logical_total as usize)];
    let t0 = Instant::now();
    let mut got = 0u64;
    c.seek(h, 0)?;
    while got < logical_total {
        let n = c.read(h, &mut buf)?;
        if n == 0 {
            break;
        }
        got += n as u64;
    }
    let dt_e = t0.elapsed();
    pool.shutdown()?;
    Ok(mbps(got, dt_e))
}

/// E4 counterpart: the same strided pattern via ROMIO-style data sieving
/// over the same striped sim disks.
pub fn strided_romio(
    ndisks: usize,
    total_bytes: u64,
    blk: u32,
    stride: u32,
) -> Result<f64> {
    let disks: Vec<Arc<dyn Disk>> = (0..ndisks)
        .map(|_| Arc::new(SimDisk::new(SimCost::paper_1998())) as Arc<dyn Disk>)
        .collect();
    let fs = RomioLike::new(disks, 64 * 1024);
    let chunk = vec![1u8; 1 << 20];
    let mut off = 0;
    while off < total_bytes {
        let n = (1u64 << 20).min(total_bytes - off);
        fs.write_contig(off, &chunk[..n as usize])?;
        off += n;
    }
    let view = AccessDesc::vector(1, blk, (stride - blk) as i64);
    let logical_total = total_bytes / stride as u64 * blk as u64;
    let mut buf = vec![0u8; (1 << 20).min(logical_total as usize)];
    let t0 = Instant::now();
    let mut got = 0u64;
    while got < logical_total {
        let n = (buf.len() as u64).min(logical_total - got);
        let r = fs.read_sieved(&view, 0, got, &mut buf[..n as usize])?;
        got += r as u64;
        if r == 0 {
            break;
        }
    }
    Ok(mbps(got, t0.elapsed()))
}

/// E4 contiguous comparison: ROMIO-like direct striped access.
pub fn contig_romio(ndisks: usize, total_bytes: u64, req_bytes: u64) -> Result<BwResult> {
    let disks: Vec<Arc<dyn Disk>> = (0..ndisks)
        .map(|_| Arc::new(SimDisk::new(SimCost::paper_1998())) as Arc<dyn Disk>)
        .collect();
    let fs = RomioLike::new(disks, 64 * 1024);
    let chunk = vec![0xA5u8; req_bytes as usize];
    let t0 = Instant::now();
    let mut off = 0;
    while off < total_bytes {
        let n = req_bytes.min(total_bytes - off);
        fs.write_contig(off, &chunk[..n as usize])?;
        off += n;
    }
    let wt = t0.elapsed();
    let mut buf = vec![0u8; req_bytes as usize];
    let t1 = Instant::now();
    let mut off = 0;
    while off < total_bytes {
        let n = req_bytes.min(total_bytes - off);
        fs.read_contig(off, &mut buf[..n as usize])?;
        off += n;
    }
    Ok(BwResult {
        write_mbps: mbps(total_bytes, wt),
        read_mbps: mbps(total_bytes, t1.elapsed()),
    })
}

/// E4/two-phase: collective interleaved read via ROMIO two-phase.
pub fn two_phase_romio(ndisks: usize, nprocs: usize, total_bytes: u64) -> Result<f64> {
    let disks: Vec<Arc<dyn Disk>> = (0..ndisks)
        .map(|_| Arc::new(SimDisk::new(SimCost::paper_1998())) as Arc<dyn Disk>)
        .collect();
    let fs = RomioLike::new(disks, 64 * 1024);
    let chunk = vec![1u8; 1 << 20];
    let mut off = 0;
    while off < total_bytes {
        let n = (1u64 << 20).min(total_bytes - off);
        fs.write_contig(off, &chunk[..n as usize])?;
        off += n;
    }
    let per = total_bytes / nprocs as u64;
    let reqs: Vec<(u64, u64)> = (0..nprocs).map(|p| (p as u64 * per, per)).collect();
    let t0 = Instant::now();
    let out = two_phase_read(&fs, &reqs)?;
    let got: u64 = out.iter().map(|b| b.len() as u64).sum();
    Ok(mbps(got, t0.elapsed()))
}

/// E6: buffer-management sweep — re-read a working set through a cache
/// of `cache_bytes`; returns (bandwidth MB/s, hit rate).
pub fn cache_sweep(
    working_set: u64,
    cache_bytes: u64,
    rounds: usize,
) -> Result<(f64, f64)> {
    let pool = ServerPool::start(1, bench_server_config(cache_bytes, 0))?;
    let mut c = pool.client()?;
    let h = c.open("ws", OpenMode::rdwr_create())?;
    let chunk = vec![7u8; 64 * 1024];
    let mut off = 0;
    while off < working_set {
        let n = (chunk.len() as u64).min(working_set - off);
        c.write_at(h, off, &chunk[..n as usize])?;
        off += n;
    }
    c.sync(h)?;
    let mut buf = vec![0u8; 64 * 1024];
    let t0 = Instant::now();
    for _ in 0..rounds {
        let mut off = 0;
        while off < working_set {
            let n = (buf.len() as u64).min(working_set - off);
            c.read_at(h, off, &mut buf[..n as usize])?;
            off += n;
        }
    }
    let el = t0.elapsed();
    let server = pool.server_ranks()[0];
    let stats = c.stats_of(server)?;
    let hits = stats.cache_hits as f64;
    let total = (stats.cache_hits + stats.cache_misses) as f64;
    pool.shutdown()?;
    Ok((mbps(working_set * rounds as u64, el), hits / total.max(1.0)))
}

/// E7: redistribution — write with BLOCK layout, read back as CYCLIC
/// slices (a different distribution than written). ViPIOS serves the new
/// view server-side; the ROMIO column re-reads with client-side sieving.
pub fn redistribution_vipios(nservers: usize, total_bytes: u64, nclients: usize) -> Result<f64> {
    let pool = ServerPool::start(nservers, bench_server_config(2 << 20, 0))?;
    {
        let mut c = pool.client()?;
        c.hint(Hint::FileAdmin(FileAdminHint {
            name: "redist".into(),
            distribution: Distribution::block_for(total_bytes, nservers as u32),
            nprocs: Some(nclients as u32),
        }))?;
        let h = c.open("redist", OpenMode::rdwr_create())?;
        let chunk = vec![3u8; 1 << 20];
        let mut off = 0;
        while off < total_bytes {
            let n = (1u64 << 20).min(total_bytes - off);
            c.write_at(h, off, &chunk[..n as usize])?;
            off += n;
        }
        c.sync(h)?;
        c.close(h)?;
        for &s in pool.server_ranks() {
            c.hint_to(s, Hint::System(crate::hints::SystemHint::DropCaches))?;
        }
        c.disconnect()?;
    }
    // read phase: each client reads its CYCLIC(64K) slice through a view
    let barrier = Arc::new(Barrier::new(nclients + 1));
    let done = Arc::new(Barrier::new(nclients + 1));
    let mut handles = Vec::new();
    for p in 0..nclients {
        let world = pool.world().clone();
        let (barrier, done) = (barrier.clone(), done.clone());
        handles.push(std::thread::spawn(move || -> Result<u64> {
            let mut c = Client::connect(&world)?;
            let h = c.open("redist", OpenMode::rdonly())?;
            let k = 64 * 1024u32;
            let dt = Datatype::darray_cyclic1(
                (total_bytes / 4) as u32,
                k / 4,
                p as u32,
                nclients as u32,
                Datatype::Basic(Basic::Int),
            )
            .map_err(anyhow::Error::from)?;
            let desc = get_view_pattern(&dt);
            c.set_view(h, 0, desc)?;
            let mut buf = vec![0u8; 1 << 20];
            barrier.wait();
            let mut got = 0u64;
            loop {
                let n = c.read(h, &mut buf)?;
                got += n as u64;
                if n < buf.len() {
                    break;
                }
            }
            done.wait();
            Ok(got)
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    done.wait();
    let mut total_got = 0u64;
    for h in handles {
        total_got += h.join().unwrap()?;
    }
    let el = t0.elapsed();
    pool.shutdown()?;
    Ok(mbps(total_got, el))
}

/// One hop of the E7b physical-redistribution bench.
#[derive(Debug, Clone)]
pub struct ReorgBench {
    pub label: String,
    /// Cross-server shuffle bandwidth (bytes_moved / wall time).
    pub shuffle_mbps: f64,
    pub bytes_moved: u64,
    /// Reorg DI messages (3 control rounds per server + data batches).
    pub di_msgs: u64,
}

/// E7b: *physical* redistribution — where E7a reads a BLOCK file through
/// CYCLIC views, this actually moves the bytes with the two-phase
/// server-to-server shuffle ([`crate::reorg`]), BLOCK -> CYCLIC(64K) and
/// back, verifying byte-identical read-back after each hop. Runs on
/// MemDisk: the object under test is the shuffle protocol, not the 1998
/// spindle model.
pub fn redistribution_physical(nservers: usize, total_bytes: u64) -> Result<Vec<ReorgBench>> {
    let pool = ServerPool::start(nservers, ServerConfig::default())?;
    let mut c = pool.client()?;
    let block = Distribution::block_for(total_bytes, nservers as u32);
    let cyclic = Distribution::Cyclic { chunk: 64 * 1024 };
    c.hint(Hint::FileAdmin(FileAdminHint {
        name: "reorg".into(),
        distribution: block,
        nprocs: Some(1),
    }))?;
    let h = c.open("reorg", OpenMode::rdwr_create())?;
    // deterministic pattern, regenerated for the verify pass
    let seed = 0xE7B;
    {
        let mut r = crate::util::XorShift64::new(seed);
        let mut chunk = vec![0u8; 1 << 20];
        let mut off = 0u64;
        while off < total_bytes {
            let n = (chunk.len() as u64).min(total_bytes - off) as usize;
            r.fill(&mut chunk[..n]);
            c.write_at(h, off, &chunk[..n])?;
            off += n as u64;
        }
    }
    c.sync(h)?;
    let mut out = Vec::new();
    for (label, target) in [("BLOCK -> CYCLIC(64K)", cyclic), ("CYCLIC(64K) -> BLOCK", block)] {
        let t0 = Instant::now();
        let rep = c.redistribute(h, target)?;
        let el = t0.elapsed();
        // byte-identical read-back under the new layout
        let mut r = crate::util::XorShift64::new(seed);
        let mut want = vec![0u8; 1 << 20];
        let mut got = vec![0u8; 1 << 20];
        let mut off = 0u64;
        while off < total_bytes {
            let n = (want.len() as u64).min(total_bytes - off) as usize;
            r.fill(&mut want[..n]);
            if c.read_at(h, off, &mut got[..n])? != n || got[..n] != want[..n] {
                anyhow::bail!("E7b: read-back mismatch after {label} at offset {off}");
            }
            off += n as u64;
        }
        out.push(ReorgBench {
            label: label.into(),
            shuffle_mbps: mbps(rep.bytes_moved, el),
            bytes_moved: rep.bytes_moved,
            di_msgs: rep.messages,
        });
    }
    pool.shutdown()?;
    Ok(out)
}

/// E9 `overlap` workload: `nclients` clients each own a private file
/// (file-per-process) striped CYCLIC(64K) over `nservers`, every server
/// with `disks_per_server` SimDisks — consecutive file ids land on
/// alternating spindles, so one server has work for all its disks as
/// soon as two clients are active. Returns aggregate cold-read MB/s.
///
/// `queue_depth` is the async-kernel knob: 1 = the blocking baseline
/// (every request serializes behind one disk op per server), > 1 = the
/// dispatch/completion engine with that coalescing window. Prefetch is
/// off so the measured win is scheduling/overlap, not readahead.
pub fn overlap_bw(
    nclients: usize,
    nservers: usize,
    disks_per_server: usize,
    queue_depth: usize,
    per_client_bytes: u64,
    req_bytes: u64,
) -> Result<f64> {
    let cfg = ServerConfig {
        disks: disks_per_server,
        kind: DiskKind::Sim(SimCost::paper_1998()),
        cache: CacheConfig { page: 64 * 1024, capacity: 2 << 20, write_back: true },
        prefetch: false,
        readahead: 0,
        request_overhead: std::time::Duration::ZERO,
        queue_depth,
        ..ServerConfig::default()
    };
    let pool = ServerPool::start(nservers, cfg)?;
    let ready = Arc::new(Barrier::new(nclients + 1));
    let go = Arc::new(Barrier::new(nclients + 1));
    let done = Arc::new(Barrier::new(nclients + 1));
    let mut handles = Vec::new();
    for cidx in 0..nclients {
        let world = pool.world().clone();
        let (ready, go, done) = (ready.clone(), go.clone(), done.clone());
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut c = Client::connect(&world)?;
            let h = c.open(&format!("ov{cidx}"), OpenMode::rdwr_create())?;
            let chunk = vec![0xC3u8; req_bytes as usize];
            let mut off = 0u64;
            while off < per_client_bytes {
                let n = req_bytes.min(per_client_bytes - off);
                c.write_at(h, off, &chunk[..n as usize])?;
                off += n;
            }
            c.sync(h)?;
            ready.wait();
            // caches dropped by the coordinator between these barriers
            go.wait();
            let mut buf = vec![0u8; req_bytes as usize];
            let mut off = 0u64;
            while off < per_client_bytes {
                let n = req_bytes.min(per_client_bytes - off);
                c.read_at(h, off, &mut buf[..n as usize])?;
                off += n;
            }
            done.wait();
            c.close(h)?;
            c.disconnect()?;
            Ok(())
        }));
    }
    ready.wait();
    {
        let mut admin = pool.client()?;
        for &s in pool.server_ranks() {
            admin.hint_to(s, Hint::System(crate::hints::SystemHint::DropCaches))?;
        }
        admin.disconnect()?;
    }
    let t0 = Instant::now();
    go.wait();
    done.wait();
    let elapsed = t0.elapsed();
    for h in handles {
        h.join().unwrap()?;
    }
    pool.shutdown()?;
    Ok(mbps(per_client_bytes * nclients as u64, elapsed))
}

/// One E11 measurement (read phase only).
#[derive(Debug, Clone, Copy)]
pub struct CollectiveRun {
    pub mbps: f64,
    /// ER + DI messages the read phase took, summed over servers
    /// (stat-sweep corrected).
    pub msgs: u64,
    /// `ServerStats::list_extents` delta over the phase.
    pub list_extents: u64,
    /// `ServerStats::coalesced_runs` delta over the phase.
    pub coalesced_runs: u64,
    /// `ServerStats::collective_windows` delta over the phase.
    pub windows: u64,
    /// `ServerStats::bytes_copied` delta over the phase (data-plane
    /// memcpys — CoW unshares plus reorg shipping; see DESIGN.md §4.7).
    pub bytes_copied: u64,
    /// `ServerStats::bytes_aliased` delta over the phase (bytes served
    /// as slices of resident cache pages or the shared zero frame).
    pub bytes_aliased: u64,
    /// Bytes the clients demanded during the phase (`total`): the
    /// denominator of the copied-per-demand-byte gate cell.
    pub demand: u64,
}

impl CollectiveRun {
    /// Data-plane copies per demanded byte — the zero-copy figure of
    /// merit. ≤ 1.0 means the read path aliases cache pages instead of
    /// flattening each response.
    pub fn copied_per_byte(&self) -> f64 {
        self.bytes_copied as f64 / self.demand.max(1) as f64
    }
}

fn coll_stat_sweep(c: &mut Client, pool: &ServerPool) -> Result<(u64, u64, u64, u64, u64, u64)> {
    let (mut msgs, mut ext, mut runs, mut win) = (0u64, 0u64, 0u64, 0u64);
    let (mut copied, mut aliased) = (0u64, 0u64);
    for &s in pool.server_ranks() {
        let st = c.stats_of(s)?;
        msgs += st.ext_requests + st.int_requests;
        ext += st.list_extents;
        runs += st.coalesced_runs;
        win += st.collective_windows;
        copied += st.bytes_copied;
        aliased += st.bytes_aliased;
    }
    Ok((msgs, ext, runs, win, copied, aliased))
}

/// E11 workload — the E4c interleaved shape: `nprocs` SPMD clients
/// cold-read interleaved contiguous blocks of one shared file, either
/// *independent* (the paper's §6.3.4 mapping of `MPI_File_read_at_all`:
/// per-process request + barrier) or *collective* (tagged list requests
/// aggregated at the home server into merged runs — two-phase I/O
/// inside VS, DESIGN.md §4.4). Returns read-phase bandwidth plus the
/// message-amplification counters.
pub fn collective_read(
    nprocs: usize,
    nservers: usize,
    total: u64,
    collective: bool,
) -> Result<CollectiveRun> {
    let mut cfg = bench_server_config(2 << 20, 0);
    // neither the byte budget nor the straggler deadline may split the
    // window mid-bench (both escape paths have their own tests) — the
    // group always completes here, so the deadline never fires
    cfg.collective_bytes = cfg.collective_bytes.max(total);
    cfg.collective_wait = std::time::Duration::from_secs(2);
    let pool = ServerPool::start(nservers, cfg)?;
    {
        let mut c = pool.client()?;
        c.hint(Hint::FileAdmin(FileAdminHint {
            name: "e11".into(),
            distribution: Distribution::block_for(total, nservers as u32),
            nprocs: Some(nprocs as u32),
        }))?;
        let h = c.open("e11", OpenMode::rdwr_create())?;
        let chunk = vec![0xE4u8; 1 << 20];
        let mut off = 0u64;
        while off < total {
            let n = (chunk.len() as u64).min(total - off);
            c.write_at(h, off, &chunk[..n as usize])?;
            off += n;
        }
        c.sync(h)?;
        for &s in pool.server_ranks() {
            c.hint_to(s, Hint::System(crate::hints::SystemHint::DropCaches))?;
        }
        c.disconnect()?;
    }
    let per = total / nprocs as u64;
    let group = ClientGroup::new(nprocs);
    let ready = Arc::new(Barrier::new(nprocs + 1));
    let start = Arc::new(Barrier::new(nprocs + 1));
    let done = Arc::new(Barrier::new(nprocs + 1));
    let exit = Arc::new(Barrier::new(nprocs + 1));
    let mut handles = Vec::new();
    for p in 0..nprocs {
        let world = pool.world().clone();
        let member = group.member(p);
        let (ready, start, done, exit) =
            (ready.clone(), start.clone(), done.clone(), exit.clone());
        handles.push(std::thread::spawn(move || -> Result<()> {
            let byte = Datatype::Basic(Basic::Byte);
            let mut c = Client::connect(&world)?;
            let mut f = MpiFile::open(&mut c, "e11", Amode::rdonly())?;
            let mut buf = vec![0u8; per as usize];
            ready.wait();
            start.wait();
            if collective {
                member.read_at_all(&mut f, &mut c, p as u64 * per, &mut buf, per, &byte)?;
            } else {
                f.read_at(&mut c, p as u64 * per, &mut buf, per, &byte)?;
                member.barrier();
            }
            done.wait();
            exit.wait();
            c.disconnect()?;
            Ok(())
        }));
    }
    let mut admin = pool.client()?;
    ready.wait();
    let before = coll_stat_sweep(&mut admin, &pool)?;
    let t0 = Instant::now();
    start.wait();
    done.wait();
    let elapsed = t0.elapsed();
    let after = coll_stat_sweep(&mut admin, &pool)?;
    exit.wait();
    for h in handles {
        h.join().unwrap()?;
    }
    admin.disconnect()?;
    pool.shutdown()?;
    Ok(CollectiveRun {
        mbps: mbps(total, elapsed),
        // the closing sweep's own Stat ERs are the only non-read
        // traffic between the sweeps
        msgs: (after.0 - before.0).saturating_sub(nservers as u64),
        list_extents: after.1 - before.1,
        coalesced_runs: after.2 - before.2,
        windows: after.3 - before.3,
        bytes_copied: after.4 - before.4,
        bytes_aliased: after.5 - before.5,
        demand: total,
    })
}

/// E10 prefetch mode under test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefetchMode {
    /// `SystemHint::Prefetch(false)` on every server — the hint-less
    /// async baseline.
    Off,
    /// Online detection only: the servers must extract the pattern from
    /// the request stream ([`crate::pattern`]).
    Pattern,
    /// Compiler-style `AccessPlan` hint listing the whole stream.
    Plan,
}

/// One E10 measurement.
#[derive(Debug, Clone, Copy)]
pub struct PrefetchRun {
    pub mbps: f64,
    /// Cache hit rate over the timed read phase.
    pub hit_rate: f64,
    /// `ServerStats::predicted_bytes` summed over servers.
    pub predicted: u64,
    /// `ServerStats::wasted_prefetch` summed over servers.
    pub wasted: u64,
}

/// E10 strided cold-read workload: one client reads every `stride`-th
/// `blk`-byte record of a `total`-byte file (BLOCK layout over
/// `nservers` SimDisk servers), spending `think_us` of compute between
/// records — the §2 pipelined-parallelism shape. With prediction or a
/// plan, the disks read record *k+1..k+w* while the client computes on
/// *k*; without, every record pays its full seek+transfer latency
/// inline.
pub fn prefetch_strided(
    mode: PrefetchMode,
    nservers: usize,
    total: u64,
    blk: u64,
    stride: u64,
    think_us: u64,
) -> Result<PrefetchRun> {
    let pool = ServerPool::start(nservers, bench_server_config(2 << 20, 0))?;
    let mut c = pool.client()?;
    c.hint(Hint::FileAdmin(FileAdminHint {
        name: "e10".into(),
        distribution: Distribution::block_for(total, nservers as u32),
        nprocs: Some(1),
    }))?;
    let h = c.open("e10", OpenMode::rdwr_create())?;
    let chunk = vec![0xE1u8; 1 << 20];
    let mut off = 0u64;
    while off < total {
        let n = (chunk.len() as u64).min(total - off);
        c.write_at(h, off, &chunk[..n as usize])?;
        off += n;
    }
    c.sync(h)?;
    for &s in pool.server_ranks() {
        c.hint_to(s, Hint::System(crate::hints::SystemHint::DropCaches))?;
    }
    let hits0: u64 = pool
        .server_ranks()
        .iter()
        .map(|&s| c.stats_of(s).map(|st| st.cache_hits).unwrap_or(0))
        .sum();
    let miss0: u64 = pool
        .server_ranks()
        .iter()
        .map(|&s| c.stats_of(s).map(|st| st.cache_misses).unwrap_or(0))
        .sum();
    let records: Vec<u64> = (0..total / stride).map(|i| i * stride).collect();
    match mode {
        PrefetchMode::Off => {
            for &s in pool.server_ranks() {
                c.hint_to(s, Hint::System(crate::hints::SystemHint::Prefetch(false)))?;
            }
        }
        PrefetchMode::Pattern => {}
        PrefetchMode::Plan => {
            c.access_plan(h, records.iter().map(|&o| (o, blk)).collect())?;
        }
    }
    let think = std::time::Duration::from_micros(think_us);
    let mut buf = vec![0u8; blk as usize];
    let t0 = Instant::now();
    for &o in &records {
        c.read_at(h, o, &mut buf)?;
        crate::disk::precise_wait(think);
    }
    let elapsed = t0.elapsed();
    let mut hits = 0u64;
    let mut misses = 0u64;
    let mut predicted = 0u64;
    let mut wasted = 0u64;
    for &s in pool.server_ranks() {
        let st = c.stats_of(s)?;
        hits += st.cache_hits;
        misses += st.cache_misses;
        predicted += st.predicted_bytes;
        wasted += st.wasted_prefetch;
    }
    hits -= hits0.min(hits);
    misses -= miss0.min(misses);
    pool.shutdown()?;
    Ok(PrefetchRun {
        mbps: mbps(records.len() as u64 * blk, elapsed),
        hit_rate: hits as f64 / (hits + misses).max(1) as f64,
        predicted,
        wasted,
    })
}

/// E10 OOC half: one cold Jacobi sweep (nb×nb blocks of
/// [`crate::runtime::BLOCK`]² f32) through the reference compute
/// backend, with and without the plan-driven tile pipeline. Returns
/// (aggregate I/O MB/s over the sweep, cache hit rate).
pub fn prefetch_ooc(plan: bool, nb: usize) -> Result<(f64, f64)> {
    use crate::runtime::{Runtime, Tensor, BLOCK};
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");
    let mut rt = Runtime::new(artifacts)?;
    let pool = ServerPool::start(2, bench_server_config(4 << 20, 0))?;
    let mut c = pool.client()?;
    let src = crate::ooc::BlockedArray::create(&mut c, "e10src", nb)?;
    let dst = crate::ooc::BlockedArray::create(&mut c, "e10dst", nb)?;
    let mut t = Tensor::zeros(vec![BLOCK, BLOCK]);
    for (i, v) in t.data.iter_mut().enumerate() {
        *v = (i % 17) as f32;
    }
    for bi in 0..nb {
        for bj in 0..nb {
            src.write_block(&mut c, bi, bj, &t)?;
        }
    }
    let hsrc = c.open("e10src", OpenMode::rdwr_create())?;
    c.sync(hsrc)?;
    for &s in pool.server_ranks() {
        c.hint_to(s, Hint::System(crate::hints::SystemHint::DropCaches))?;
    }
    let hits0: u64 = pool
        .server_ranks()
        .iter()
        .map(|&s| c.stats_of(s).map(|st| st.cache_hits).unwrap_or(0))
        .sum();
    let miss0: u64 = pool
        .server_ranks()
        .iter()
        .map(|&s| c.stats_of(s).map(|st| st.cache_misses).unwrap_or(0))
        .sum();
    let t0 = Instant::now();
    let stats = crate::ooc::jacobi_sweep(&mut c, &mut rt, &src, &dst, plan)?;
    let elapsed = t0.elapsed();
    let mut hits = 0u64;
    let mut misses = 0u64;
    for &s in pool.server_ranks() {
        let st = c.stats_of(s)?;
        hits += st.cache_hits;
        misses += st.cache_misses;
    }
    hits -= hits0.min(hits);
    misses -= miss0.min(misses);
    pool.shutdown()?;
    Ok((
        mbps(stats.bytes_read + stats.bytes_written, elapsed),
        hits as f64 / (hits + misses).max(1) as f64,
    ))
}

// ------------------------------------------------------ deployment rig

/// E12 — real-process deployment bench (DESIGN.md §4.6): spawns
/// `vipios-server` / `vipios-client` release binaries, one OS process
/// each, meshed over unix-domain (or TCP) sockets, and merges the
/// clients' one-line JSON reports into aggregate bandwidth + latency
/// percentiles. Every read is byte-verified inside the client binary
/// against a pure function of file offset, so a misrouted frame or a
/// stale cache page fails the run, not just slows it. Unlike the other
/// experiments this one needs the deployment binaries built first, so
/// it runs as `vipios bench deploy` and is not part of `bench all`.
pub mod deploy {
    use std::io::{BufRead, BufReader};
    use std::path::PathBuf;
    use std::process::{Child, Command, Stdio};
    use std::time::{Duration, Instant};

    use anyhow::Result;

    use super::print_table;
    use crate::util::mbps;

    /// Log2-µs histogram shape — must match `vipios-client`.
    const HIST_BUCKETS: usize = 32;

    /// One client process's parsed report line.
    struct ClientReport {
        wrote: u64,
        read: u64,
        verify_errors: u64,
        write_us: Vec<u64>,
        read_us: Vec<u64>,
    }

    /// Aggregated outcome of one workload run.
    pub struct DeployRun {
        /// `(written + read bytes) / wall clock` across all clients.
        pub mbps: f64,
        /// Latency percentiles over every blocking client op (writes
        /// and reads), from the merged log2 histograms.
        pub p50_us: u64,
        pub p95_us: u64,
        pub p99_us: u64,
        pub verify_errors: u64,
    }

    // ---- hand-rolled scanners for the client's one-line JSON --------

    fn num_field(line: &str, key: &str) -> Option<u64> {
        let pat = format!("\"{key}\":");
        let at = line.find(&pat)? + pat.len();
        let rest = &line[at..];
        let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
        rest[..end].parse().ok()
    }

    fn arr_field(line: &str, key: &str) -> Option<Vec<u64>> {
        let pat = format!("\"{key}\":[");
        let at = line.find(&pat)? + pat.len();
        let rest = &line[at..];
        let end = rest.find(']')?;
        rest[..end].split(',').map(|c| c.trim().parse().ok()).collect()
    }

    fn parse_report(line: &str) -> Result<ClientReport> {
        let num = |k: &str| {
            num_field(line, k)
                .ok_or_else(|| anyhow::anyhow!("field {k:?} missing in client report: {line}"))
        };
        let arr = |k: &str| {
            arr_field(line, k)
                .ok_or_else(|| anyhow::anyhow!("array {k:?} missing in client report: {line}"))
        };
        Ok(ClientReport {
            wrote: num("wrote")?,
            read: num("read")?,
            verify_errors: num("verify_errors")?,
            write_us: arr("write_us")?,
            read_us: arr("read_us")?,
        })
    }

    /// q-th percentile of a merged log2 histogram, reported as the
    /// matched bucket's geometric midpoint (`1.5 * 2^i` µs).
    fn percentile(hist: &[u64], q: f64) -> u64 {
        let total: u64 = hist.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &n) in hist.iter().enumerate() {
            seen += n;
            if seen >= target {
                return (1u64 << i) * 3 / 2;
            }
        }
        (1u64 << (hist.len() - 1)) * 3 / 2
    }

    /// The deployment binaries live next to whatever binary is running
    /// (`target/<profile>/`, or one level up from `deps/` for tests).
    fn bin_path(name: &str) -> Result<PathBuf> {
        let mut p = std::env::current_exe()?;
        p.pop();
        if p.ends_with("deps") {
            p.pop();
        }
        p.push(name);
        anyhow::ensure!(
            p.exists(),
            "{} not found — build the deployment binaries first (`cargo build --release`)",
            p.display()
        );
        Ok(p)
    }

    /// Which socket flavour this platform's rig uses.
    pub fn transport_kind() -> &'static str {
        if cfg!(unix) {
            "uds"
        } else {
            "tcp"
        }
    }

    fn wait_or_kill(mut child: Child, what: &str, limit: Duration) -> Result<()> {
        let start = Instant::now();
        loop {
            if let Some(st) = child.try_wait()? {
                anyhow::ensure!(st.success(), "{what} exited with {st}");
                return Ok(());
            }
            if start.elapsed() >= limit {
                let _ = child.kill();
                let _ = child.wait();
                anyhow::bail!("{what} hung past {limit:?} and was killed");
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// One deployment: binary paths, socket addresses, workload sizing.
    struct Rig {
        server_bin: PathBuf,
        client_bin: PathBuf,
        scratch: PathBuf,
        /// Comma-joined `--servers` value.
        addrs: String,
        nservers: usize,
        nclients: usize,
        bytes: u64,
        req: u64,
    }

    impl Rig {
        fn new(nservers: usize, nclients: usize, bytes: u64, req: u64, tag: &str) -> Result<Rig> {
            let scratch =
                std::env::temp_dir().join(format!("vipios-deploy-{}-{tag}", std::process::id()));
            std::fs::create_dir_all(&scratch)?;
            let addrs: Vec<String> = if cfg!(unix) {
                (0..nservers).map(|r| format!("uds:{}/vs{r}.sock", scratch.display())).collect()
            } else {
                // no ephemeral-port handshake across processes: spread a
                // pid-derived base to keep parallel runs apart
                let base = 20000 + (std::process::id() % 20000) as usize;
                (0..nservers).map(|r| format!("tcp:127.0.0.1:{}", base + r)).collect()
            };
            Ok(Rig {
                server_bin: bin_path("vipios-server")?,
                client_bin: bin_path("vipios-client")?,
                scratch,
                addrs: addrs.join(","),
                nservers,
                nclients,
                bytes,
                req,
            })
        }

        fn spawn_servers(&self) -> Result<Vec<Child>> {
            let mut servers = Vec::new();
            for r in 0..self.nservers {
                let child = Command::new(&self.server_bin)
                    .args(["--rank", &r.to_string(), "--servers", &self.addrs])
                    .stdout(Stdio::piped())
                    .stderr(Stdio::inherit())
                    .spawn()
                    .map_err(|e| anyhow::anyhow!("spawning server {r}: {e}"))?;
                servers.push(child);
            }
            // startup barrier: every server prints READY once its event
            // loop is about to serve
            for (r, child) in servers.iter_mut().enumerate() {
                let out = child.stdout.take().ok_or_else(|| anyhow::anyhow!("no stdout"))?;
                let mut line = String::new();
                BufReader::new(out).read_line(&mut line)?;
                anyhow::ensure!(
                    line.starts_with("READY"),
                    "server {r} failed before READY (got {line:?})"
                );
            }
            Ok(servers)
        }

        fn client_cmd(&self, id: usize, workload: &str) -> Command {
            let mut cmd = Command::new(&self.client_bin);
            cmd.args(["--servers", &self.addrs, "--id", &id.to_string()])
                .args(["--workload", workload])
                .args(["--bytes", &self.bytes.to_string(), "--req", &self.req.to_string()]);
            if workload == "collective" {
                cmd.args(["--nprocs", &self.nclients.to_string(), "--group", "1"]);
            }
            cmd
        }

        fn run(&self, workload: &str) -> Result<DeployRun> {
            let mut servers = self.spawn_servers()?;
            let t0 = Instant::now();
            let mut clients = Vec::new();
            for id in 0..self.nclients {
                let child = self
                    .client_cmd(id, workload)
                    .stdout(Stdio::piped())
                    .stderr(Stdio::inherit())
                    .spawn()
                    .map_err(|e| anyhow::anyhow!("spawning client {id}: {e}"))?;
                clients.push(child);
            }
            let mut reports = Vec::new();
            for (id, child) in clients.into_iter().enumerate() {
                let out = child.wait_with_output()?;
                anyhow::ensure!(out.status.success(), "client {id} failed ({})", out.status);
                let text = String::from_utf8_lossy(&out.stdout);
                let line = text
                    .lines()
                    .rev()
                    .find(|l| l.trim_start().starts_with('{'))
                    .ok_or_else(|| anyhow::anyhow!("client {id} printed no report"))?;
                reports.push(parse_report(line)?);
            }
            let elapsed = t0.elapsed();
            // orderly teardown: a bare client asks every server to exit
            let stopper = self
                .client_cmd(self.nclients, "none")
                .arg("--shutdown")
                .stdout(Stdio::null())
                .stderr(Stdio::inherit())
                .spawn()?;
            wait_or_kill(stopper, "shutdown client", Duration::from_secs(30))?;
            for (r, s) in servers.drain(..).enumerate() {
                wait_or_kill(s, &format!("server {r}"), Duration::from_secs(30))?;
            }
            let mut hist = vec![0u64; HIST_BUCKETS];
            let mut moved = 0u64;
            let mut verify = 0u64;
            for rep in &reports {
                moved += rep.wrote + rep.read;
                verify += rep.verify_errors;
                for (i, &n) in rep.write_us.iter().enumerate().take(HIST_BUCKETS) {
                    hist[i] += n;
                }
                for (i, &n) in rep.read_us.iter().enumerate().take(HIST_BUCKETS) {
                    hist[i] += n;
                }
            }
            Ok(DeployRun {
                mbps: mbps(moved, elapsed),
                p50_us: percentile(&hist, 0.50),
                p95_us: percentile(&hist, 0.95),
                p99_us: percentile(&hist, 0.99),
                verify_errors: verify,
            })
        }
    }

    impl Drop for Rig {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.scratch);
        }
    }

    /// Run one workload end to end: N server + M client OS processes.
    pub fn run_one(
        workload: &str,
        nservers: usize,
        nclients: usize,
        bytes: u64,
        req: u64,
    ) -> Result<DeployRun> {
        Rig::new(nservers, nclients, bytes, req, workload)?.run(workload)
    }

    /// E12 table: one row per workload mix, 2 servers x 4 clients.
    pub fn table(quick: bool) -> Result<()> {
        let (nservers, nclients) = (2, 4);
        let mb = 1u64 << 20;
        let (bytes, req) = if quick { (mb, 64 * 1024) } else { (8 * mb, 64 * 1024) };
        let mut rows = Vec::new();
        for wl in ["seq", "strided", "collective"] {
            let r = run_one(wl, nservers, nclients, bytes, req)?;
            anyhow::ensure!(
                r.verify_errors == 0,
                "E12 {wl}: {} corrupted byte(s) survived the read-back",
                r.verify_errors
            );
            rows.push(vec![
                wl.to_string(),
                transport_kind().to_string(),
                format!("{:.1}", r.mbps),
                r.p50_us.to_string(),
                r.p95_us.to_string(),
                r.p99_us.to_string(),
                r.verify_errors.to_string(),
            ]);
        }
        print_table(
            "E12 (§4.6) real-process deployment — 2 servers x 4 clients, socket transport",
            &["workload", "transport", "MB/s", "p50(us)", "p95(us)", "p99(us)", "verify errors"],
            &rows,
        );
        Ok(())
    }
}

// --------------------------------------------- E13 multi-tenant benchmark

/// E13 — server-global scheduling under multi-tenant contention
/// (DESIGN.md §4.8): mixed sequential / strided / collective client
/// classes share a 2-server pool, once with arbitration disabled
/// (unlimited prefetch budget, best-effort admission) and once with the
/// fair-share budget plus QoS rate limits on the sequential aggressors.
/// The headline is the strided class's p99 latency: with arbitration on
/// it must drop to <= 0.7x the unarbitrated run (the CI gate treats the
/// ratio column as a ceiling).
pub mod tenants {
    use super::*;
    use crate::hints::SystemHint;

    const HIST_BUCKETS: usize = 32;
    const PAGE: u64 = 64 * 1024;

    /// Per-seq-client QoS class when arbitration is on: 2 MB/s with one
    /// page of burst. Aggressive enough that the class still makes
    /// progress, tight enough that the victims' tail visibly recovers.
    const QOS_RATE: u64 = 2 * MB;
    const QOS_BURST: u64 = 2 * PAGE;

    const MB: u64 = 1 << 20;

    fn bucket(us: u64) -> usize {
        let b = 63 - us.max(1).leading_zeros() as usize;
        b.min(HIST_BUCKETS - 1)
    }

    /// Same log2-midpoint estimator as the E12 deploy histograms.
    fn percentile(hist: &[u64], q: f64) -> u64 {
        let total: u64 = hist.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &n) in hist.iter().enumerate() {
            seen += n;
            if seen >= target {
                return (1u64 << i) * 3 / 2;
            }
        }
        (1u64 << (hist.len() - 1)) * 3 / 2
    }

    /// One thread's share of a class run: op-latency histogram, bytes
    /// moved, and its own wall time (class wall = max over members).
    struct MemberOut {
        hist: Vec<u64>,
        bytes: u64,
        us: u64,
    }

    /// Aggregated per-class outcome.
    pub struct ClassOut {
        pub clients: usize,
        pub mbps: f64,
        pub p50_us: u64,
        pub p95_us: u64,
        pub p99_us: u64,
    }

    /// One full mixed-tenant run (all three classes concurrently).
    pub struct TenantRun {
        pub seq: ClassOut,
        pub strided: ClassOut,
        pub collective: ClassOut,
        pub admitted: u64,
        pub deferred: u64,
        pub shed: u64,
    }

    fn merge(outs: Vec<MemberOut>) -> ClassOut {
        let clients = outs.len();
        let mut hist = vec![0u64; HIST_BUCKETS];
        let mut bytes = 0u64;
        let mut wall_us = 0u64;
        for o in outs {
            for (i, n) in o.hist.into_iter().enumerate() {
                hist[i] += n;
            }
            bytes += o.bytes;
            wall_us = wall_us.max(o.us);
        }
        ClassOut {
            clients,
            mbps: mbps(bytes, std::time::Duration::from_micros(wall_us.max(1))),
            p50_us: percentile(&hist, 0.50),
            p95_us: percentile(&hist, 0.95),
            p99_us: percentile(&hist, 0.99),
        }
    }

    fn tenant_server_config(arb: bool, coll_bytes: u64) -> ServerConfig {
        ServerConfig {
            disks: 1,
            // paper_1998 scaled once more (1 ms -> 0.2 ms seek) so the
            // full run stays CI-sized while queueing still dominates
            kind: DiskKind::Sim(SimCost {
                seek_ns: 200_000,
                bytes_per_s: 100_000_000,
                op_ns: 20_000,
            }),
            cache: CacheConfig { page: PAGE, capacity: 2 * MB, write_back: true },
            prefetch: true,
            readahead: 256 * 1024,
            queue_depth: 8,
            // the arbitration switch: one page-run of global prefetch
            // budget vs effectively unlimited
            prefetch_budget: if arb { 4 * PAGE } else { u64::MAX },
            collective_bytes: coll_bytes.max(8 * MB),
            collective_wait: std::time::Duration::from_secs(2),
            ..ServerConfig::default()
        }
    }

    /// Write + admin-register one benchmark file, then drop caches.
    fn prime_file(pool: &ServerPool, name: &str, total: u64, nprocs: u32) -> Result<()> {
        let ns = pool.server_ranks().len() as u32;
        let mut c = pool.client()?;
        c.hint(Hint::FileAdmin(FileAdminHint {
            name: name.into(),
            distribution: Distribution::block_for(total, ns),
            nprocs: Some(nprocs),
        }))?;
        let h = c.open(name, OpenMode::rdwr_create())?;
        let chunk = vec![0x13u8; (1 << 20).min(total as usize)];
        let mut off = 0u64;
        while off < total {
            let n = (chunk.len() as u64).min(total - off);
            c.write_at(h, off, &chunk[..n as usize])?;
            off += n;
        }
        c.sync(h)?;
        c.close(h)?;
        for &s in pool.server_ranks() {
            c.hint_to(s, Hint::System(SystemHint::DropCaches))?;
        }
        c.disconnect()?;
        Ok(())
    }

    /// Run the three classes concurrently against one pool and collect
    /// per-class latency histograms plus the admission counters.
    fn run_mixed(arb: bool, quick: bool) -> Result<TenantRun> {
        let ns = 2;
        let ncls = if quick { 4 } else { 8 };
        let seq_per = if quick { 2 * MB } else { 4 * MB };
        let seq_file = seq_per * ncls as u64;
        let str_file = if quick { 4 * MB } else { 8 * MB };
        let str_blk = 8 * 1024u64;
        let str_stride = 64 * 1024u64;
        let coll_file = if quick { 2 * MB } else { 4 * MB };
        let coll_chunk = 32 * 1024u64;

        let pool = ServerPool::start(ns, tenant_server_config(arb, coll_file))?;
        prime_file(&pool, "t_seq", seq_file, ncls as u32)?;
        prime_file(&pool, "t_str", str_file, ncls as u32)?;
        prime_file(&pool, "t_coll", coll_file, ncls as u32)?;

        let total_threads = 3 * ncls;
        let start = Arc::new(Barrier::new(total_threads + 1));
        let group = ClientGroup::new(ncls);

        // --- sequential aggressors: big back-to-back reads; with
        // arbitration on they self-declare a QoS class at every server
        let mut seq_handles = Vec::new();
        for cidx in 0..ncls {
            let world = pool.world().clone();
            let servers: Vec<_> = pool.server_ranks().to_vec();
            let start = start.clone();
            seq_handles.push(std::thread::spawn(move || -> Result<MemberOut> {
                let mut c = Client::connect(&world)?;
                if arb {
                    for &s in &servers {
                        c.hint_to(
                            s,
                            Hint::System(SystemHint::Qos { rate: QOS_RATE, burst: QOS_BURST }),
                        )?;
                    }
                }
                let h = c.open("t_seq", OpenMode::rdonly())?;
                let base = cidx as u64 * seq_per;
                let mut buf = vec![0u8; PAGE as usize];
                let mut hist = vec![0u64; HIST_BUCKETS];
                start.wait();
                let t0 = Instant::now();
                let mut off = base;
                while off < base + seq_per {
                    let t = Instant::now();
                    c.read_at(h, off, &mut buf)?;
                    hist[bucket(t.elapsed().as_micros() as u64)] += 1;
                    off += PAGE;
                }
                let us = t0.elapsed().as_micros() as u64;
                c.close(h)?;
                c.disconnect()?;
                Ok(MemberOut { hist, bytes: seq_per, us })
            }));
        }

        // --- strided victims: small block every `str_stride` bytes, a
        // regular pattern the detector turns into strided prefetch
        let mut str_handles = Vec::new();
        for cidx in 0..ncls {
            let world = pool.world().clone();
            let start = start.clone();
            str_handles.push(std::thread::spawn(move || -> Result<MemberOut> {
                let mut c = Client::connect(&world)?;
                let h = c.open("t_str", OpenMode::rdonly())?;
                let lane = cidx as u64 * str_blk;
                let mut buf = vec![0u8; str_blk as usize];
                let mut hist = vec![0u64; HIST_BUCKETS];
                let mut bytes = 0u64;
                start.wait();
                let t0 = Instant::now();
                let mut off = lane;
                while off + str_blk <= str_file {
                    let t = Instant::now();
                    c.read_at(h, off, &mut buf)?;
                    hist[bucket(t.elapsed().as_micros() as u64)] += 1;
                    bytes += str_blk;
                    off += str_stride;
                }
                let us = t0.elapsed().as_micros() as u64;
                c.close(h)?;
                c.disconnect()?;
                Ok(MemberOut { hist, bytes, us })
            }));
        }

        // --- collective class: lockstep read_at_all rounds (ViMPIOS
        // layer), per-round latency includes the group synchronisation
        let mut coll_handles = Vec::new();
        let rounds = coll_file / (coll_chunk * ncls as u64);
        for p in 0..ncls {
            let world = pool.world().clone();
            let member = group.member(p);
            let start = start.clone();
            coll_handles.push(std::thread::spawn(move || -> Result<MemberOut> {
                let byte = Datatype::Basic(Basic::Byte);
                let mut c = Client::connect(&world)?;
                let mut f = MpiFile::open(&mut c, "t_coll", Amode::rdonly())?;
                let mut buf = vec![0u8; coll_chunk as usize];
                let mut hist = vec![0u64; HIST_BUCKETS];
                let mut bytes = 0u64;
                start.wait();
                let t0 = Instant::now();
                for r in 0..rounds {
                    let off = r * coll_chunk * ncls as u64 + p as u64 * coll_chunk;
                    let t = Instant::now();
                    member.read_at_all(&mut f, &mut c, off, &mut buf, coll_chunk, &byte)?;
                    hist[bucket(t.elapsed().as_micros() as u64)] += 1;
                    bytes += coll_chunk;
                }
                let us = t0.elapsed().as_micros() as u64;
                c.disconnect()?;
                Ok(MemberOut { hist, bytes, us })
            }));
        }

        start.wait();
        let seq: Vec<MemberOut> =
            seq_handles.into_iter().map(|h| h.join().unwrap()).collect::<Result<_>>()?;
        let strided: Vec<MemberOut> =
            str_handles.into_iter().map(|h| h.join().unwrap()).collect::<Result<_>>()?;
        let coll: Vec<MemberOut> =
            coll_handles.into_iter().map(|h| h.join().unwrap()).collect::<Result<_>>()?;

        let mut admitted = 0u64;
        let mut deferred = 0u64;
        let mut shed = 0u64;
        {
            let mut admin = pool.client()?;
            for &s in pool.server_ranks() {
                let st = admin.stats_of(s)?;
                admitted += st.admitted;
                deferred += st.deferred;
                shed += st.shed;
            }
            admin.disconnect()?;
        }
        pool.shutdown()?;
        Ok(TenantRun {
            seq: merge(seq),
            strided: merge(strided),
            collective: merge(coll),
            admitted,
            deferred,
            shed,
        })
    }

    /// Overload scenario: one client declares a starvation-rate QoS
    /// class, then floods a single server with async reads far past the
    /// deferral depth. The tail of the flood must be shed with error
    /// acks (not dropped, not deadlocked); releasing the class (rate 0)
    /// replays the survivors.
    fn overload() -> Result<(u64, u64, u64)> {
        let pool = ServerPool::start(1, tenant_server_config(true, 8 * MB))?;
        prime_file(&pool, "t_over", MB, 1)?;
        let server = pool.server_ranks()[0];
        let mut c = pool.client()?;
        // rate 1 B/s: nothing deferred can drain during the flood
        c.hint_to(server, Hint::System(SystemHint::Qos { rate: 1, burst: 4096 }))?;
        let h = c.open("t_over", OpenMode::rdonly())?;
        let flood = 40usize;
        let mut ops = Vec::new();
        for _ in 0..flood {
            ops.push(c.iread_at(h, 0, 4096)?);
        }
        // release the class: deferred survivors replay, floor the rest
        c.hint_to(server, Hint::System(SystemHint::Qos { rate: 0, burst: 0 }))?;
        let mut ok = 0usize;
        let mut errs = 0usize;
        for op in ops {
            match c.wait(op) {
                Ok(_) => ok += 1,
                Err(_) => errs += 1,
            }
        }
        anyhow::ensure!(ok + errs == flood, "overload flood lost acks: {ok}+{errs}");
        anyhow::ensure!(errs > 0, "overload flood was never shed");
        let st = c.stats_of(server)?;
        c.close(h)?;
        c.disconnect()?;
        pool.shutdown()?;
        anyhow::ensure!(st.shed > 0, "server counted no shed admissions");
        anyhow::ensure!(st.shed <= st.deferred, "shed exceeds deferred");
        Ok((st.admitted, st.deferred, st.shed))
    }

    fn class_row(name: &str, arb: bool, c: &ClassOut, shed: u64) -> Vec<String> {
        vec![
            name.to_string(),
            if arb { "on" } else { "off" }.to_string(),
            c.clients.to_string(),
            format!("{:.1}", c.mbps),
            c.p50_us.to_string(),
            c.p95_us.to_string(),
            c.p99_us.to_string(),
            shed.to_string(),
        ]
    }

    /// E13 driver: off run, on run, headline ratio, overload scenario.
    pub fn table(quick: bool) -> Result<()> {
        let off = run_mixed(false, quick)?;
        let on = run_mixed(true, quick)?;
        // blocking clients keep <= 1 op in flight per server, so the
        // bounded deferral queue can never trip its depth here
        anyhow::ensure!(off.shed == 0, "shed {} != 0 in unarbitrated run", off.shed);
        anyhow::ensure!(on.shed == 0, "shed {} != 0 in arbitrated run", on.shed);
        let mut rows = Vec::new();
        for (run, arb) in [(&off, false), (&on, true)] {
            rows.push(class_row("seq", arb, &run.seq, run.shed));
            rows.push(class_row("strided", arb, &run.strided, run.shed));
            rows.push(class_row("collective", arb, &run.collective, run.shed));
        }
        print_table(
            "E13 (§4.8) multi-tenant arbitration — 3 classes x 2 servers",
            &["class", "arb", "clients", "MB/s", "p50(us)", "p95(us)", "p99(us)", "shed"],
            &rows,
        );
        let ratio = on.strided.p99_us as f64 / off.strided.p99_us.max(1) as f64;
        print_table(
            "E13 headline — strided-class tail latency, arbitration on vs off",
            &["metric", "off(us)", "on(us)", "p99 on/off"],
            &[vec![
                "strided p99".into(),
                off.strided.p99_us.to_string(),
                on.strided.p99_us.to_string(),
                format!("{ratio:.3}"),
            ]],
        );
        let (adm, def, shed) = overload()?;
        print_table(
            "E13 overload — QoS depth trip sheds with error acks",
            &["scenario", "admitted", "deferred", "shed"],
            &[vec!["flood x40 @ rate 1B/s".into(), adm.to_string(), def.to_string(), shed.to_string()]],
        );
        Ok(())
    }
}

// ------------------------------------------------------- table runners

/// Full Chapter-8 table regeneration, shared by `cargo bench`,
/// `examples/bench_tables` and `vipios bench`.
pub mod tables {
    use super::*;

    const MB: u64 = 1 << 20;

    fn sizes(quick: bool) -> (u64, u64) {
        // (file size, request size)
        if quick {
            (4 * MB, 64 * 1024)
        } else {
            (16 * MB, 64 * 1024)
        }
    }

    /// E1 — §8.2.1 dedicated I/O nodes: bandwidth vs (clients, servers).
    pub fn dedicated(quick: bool) -> Result<()> {
        let (file, req) = sizes(quick);
        let clients = if quick { vec![1, 4] } else { vec![1, 2, 4, 8] };
        let servers = if quick { vec![1, 4] } else { vec![1, 2, 4] };
        let mut rows = Vec::new();
        for &nc in &clients {
            for &ns in &servers {
                let r = vipios_shared_file(nc, ns, file, req, MB, 0)?;
                rows.push(vec![
                    nc.to_string(),
                    ns.to_string(),
                    format!("{:.1}", r.write_mbps),
                    format!("{:.1}", r.read_mbps),
                ]);
            }
        }
        print_table(
            "E1 (§8.2.1) dedicated I/O nodes — aggregate bandwidth",
            &["clients", "servers", "write MB/s", "read MB/s"],
            &rows,
        );
        Ok(())
    }

    /// E2 — §8.2.2 non-dedicated I/O nodes (CPU shared with compute).
    pub fn nondedicated(quick: bool) -> Result<()> {
        let (file, req) = sizes(quick);
        let combos = if quick { vec![(2, 2)] } else { vec![(2, 2), (4, 2), (4, 4)] };
        let mut rows = Vec::new();
        for &(nc, ns) in &combos {
            let ded = vipios_shared_file(nc, ns, file, req, MB, 0)?;
            let non = vipios_shared_file(nc, ns, file, req, MB, 1000)?;
            rows.push(vec![
                nc.to_string(),
                ns.to_string(),
                format!("{:.1}", ded.read_mbps),
                format!("{:.1}", non.read_mbps),
                format!("{:.2}x", ded.read_mbps / non.read_mbps.max(1e-9)),
            ]);
        }
        print_table(
            "E2 (§8.2.2) non-dedicated I/O nodes — read bandwidth",
            &["clients", "servers", "dedicated", "non-dedicated", "slowdown"],
            &rows,
        );
        Ok(())
    }

    /// E3 — §8.3.1 ViPIOS vs UNIX file I/O vs host-centralised MPI.
    pub fn vs_unix(quick: bool) -> Result<()> {
        let (file, req) = sizes(quick);
        let nclients = if quick { vec![1, 4] } else { vec![1, 2, 4, 8] };
        let mut rows = Vec::new();
        for &nc in &nclients {
            let v = vipios_shared_file(nc, 4.min(nc.max(2)), file, req, MB, 0)?;
            let h = host_centralized_file(nc, file, req)?;
            let u = if nc == 1 {
                unix_seq_file(file, req)?
            } else {
                // a single stream regardless of process count
                unix_seq_file(file, req)?
            };
            rows.push(vec![
                nc.to_string(),
                format!("{:.1}", v.read_mbps),
                format!("{:.1}", h.read_mbps),
                format!("{:.1}", u.read_mbps),
            ]);
        }
        print_table(
            "E3 (§8.3.1) read bandwidth: ViPIOS vs host-node MPI vs UNIX",
            &["clients", "ViPIOS", "host-MPI", "UNIX seq"],
            &rows,
        );
        Ok(())
    }

    /// E4 — §8.3.2/§8.4.2 ViMPIOS vs ROMIO-like: contiguous + strided +
    /// two-phase collective.
    pub fn vs_romio(quick: bool) -> Result<()> {
        let (file, req) = sizes(quick);
        let ns = 4;
        let v = vipios_shared_file(1, ns, file, req, MB, 0)?;
        let r = contig_romio(ns, file, req)?;
        print_table(
            "E4a (§8.3.2) contiguous read/write — ViMPIOS vs ROMIO-like",
            &["system", "write MB/s", "read MB/s"],
            &[
                vec!["ViMPIOS".into(), format!("{:.1}", v.write_mbps), format!("{:.1}", v.read_mbps)],
                vec!["ROMIO-like".into(), format!("{:.1}", r.write_mbps), format!("{:.1}", r.read_mbps)],
            ],
        );
        let mut rows = Vec::new();
        for &(blk, stride) in &[(4096u32, 8192u32), (4096, 16384), (1024, 8192)] {
            let vi = strided_vipios(ns, file, blk, stride)?;
            let ro = strided_romio(ns, file, blk, stride)?;
            rows.push(vec![
                format!("{blk}/{stride}"),
                format!("{vi:.1}"),
                format!("{ro:.1}"),
                format!("{:.2}x", vi / ro.max(1e-9)),
            ]);
        }
        print_table(
            "E4b strided read (blk/stride bytes) — ViMPIOS view vs ROMIO sieving",
            &["pattern", "ViMPIOS", "ROMIO-like", "speedup"],
            &rows,
        );
        let tp = two_phase_romio(ns, 4, file)?;
        print_table(
            "E4c collective interleaved read",
            &["system", "MB/s"],
            &[vec!["ROMIO two-phase".into(), format!("{tp:.1}")]],
        );
        Ok(())
    }

    /// E5 — §8.4.1 scalability with file size.
    pub fn scalability(quick: bool) -> Result<()> {
        let sizes: Vec<u64> = if quick {
            vec![MB, 4 * MB]
        } else {
            vec![MB, 4 * MB, 16 * MB, 64 * MB]
        };
        let mut rows = Vec::new();
        for &s in &sizes {
            let r = vipios_shared_file(4, 4, s, 64 * 1024, MB, 0)?;
            rows.push(vec![
                crate::util::fmt_bytes(s),
                format!("{:.1}", r.write_mbps),
                format!("{:.1}", r.read_mbps),
            ]);
        }
        print_table(
            "E5 (§8.4.1) scalability with file size (4 clients, 4 servers)",
            &["file size", "write MB/s", "read MB/s"],
            &rows,
        );
        Ok(())
    }

    /// E6 — §8.5 buffer management: cache-size sweep.
    pub fn buffer(quick: bool) -> Result<()> {
        let ws = if quick { 4 * MB } else { 16 * MB };
        let caches: Vec<u64> = if quick {
            vec![MB, 8 * MB]
        } else {
            vec![MB, 2 * MB, 4 * MB, 8 * MB, 32 * MB]
        };
        let mut rows = Vec::new();
        for &cb in &caches {
            let (bw, hit) = cache_sweep(ws, cb, 3)?;
            rows.push(vec![
                crate::util::fmt_bytes(cb),
                format!("{bw:.1}"),
                format!("{:.1}%", hit * 100.0),
            ]);
        }
        print_table(
            "E6 (§8.5) buffer management — re-read bandwidth vs cache size",
            &["cache", "MB/s", "hit rate"],
            &rows,
        );
        Ok(())
    }

    /// E7a — logical redistribution (write BLOCK, read CYCLIC view) and
    /// E7b — physical redistribution (two-phase reorg shuffle).
    pub fn redistribution(quick: bool) -> Result<()> {
        let (file, _) = sizes(quick);
        let bw = redistribution_vipios(4, file, 4)?;
        let sieve = strided_romio(4, file, 64 * 1024, 4 * 64 * 1024)?;
        print_table(
            "E7a logical redistribution: write BLOCK, read CYCLIC slices",
            &["system", "MB/s"],
            &[
                vec!["ViPIOS (view, server-side)".into(), format!("{bw:.1}")],
                vec!["ROMIO-like (client sieve)".into(), format!("{sieve:.1}")],
            ],
        );
        // E7b physically moves the bytes (64 MiB in full mode)
        let total = if quick { 8 * MB } else { 64 * MB };
        let hops = redistribution_physical(4, total)?;
        let rows: Vec<Vec<String>> = hops
            .iter()
            .map(|r| {
                vec![
                    r.label.clone(),
                    format!("{:.1}", r.shuffle_mbps),
                    crate::util::fmt_bytes(r.bytes_moved),
                    r.di_msgs.to_string(),
                ]
            })
            .collect();
        print_table(
            &format!(
                "E7b physical redistribution ({} file, 4 servers, MemDisk, byte-verified)",
                crate::util::fmt_bytes(total)
            ),
            &["hop", "shuffle MB/s", "bytes moved", "DI msgs"],
            &rows,
        );
        Ok(())
    }

    /// Ablations over the design choices DESIGN.md calls out: sequential
    /// readahead, delayed writes (write-back), request size, and the
    /// hint-driven layout (static fit) vs the default heuristic.
    pub fn ablation(quick: bool) -> Result<()> {
        let (file, req) = sizes(quick);

        // (a) readahead prefetch on/off — sequential single-client read
        let bw = |prefetch: bool| -> Result<f64> {
            let mut cfg = bench_server_config(MB, 0);
            cfg.prefetch = prefetch;
            let pool = ServerPool::start(2, cfg)?;
            let mut c = pool.client()?;
            let h = c.open("abl", OpenMode::rdwr_create())?;
            let chunk = vec![1u8; req as usize];
            let mut off = 0;
            while off < file {
                c.write_at(h, off, &chunk)?;
                off += req;
            }
            c.sync(h)?;
            for &s in pool.server_ranks() {
                c.hint_to(s, Hint::System(crate::hints::SystemHint::DropCaches))?;
            }
            let mut buf = vec![0u8; req as usize];
            let t0 = Instant::now();
            let mut off = 0;
            while off < file {
                c.read_at(h, off, &mut buf)?;
                off += req;
            }
            let el = t0.elapsed();
            pool.shutdown()?;
            Ok(mbps(file, el))
        };
        let with_ra = bw(true)?;
        let without_ra = bw(false)?;
        print_table(
            "A1 ablation: sequential readahead (1 client, 2 servers)",
            &["readahead", "read MB/s"],
            &[
                vec!["on".into(), format!("{with_ra:.1}")],
                vec!["off".into(), format!("{without_ra:.1}")],
            ],
        );

        // (b) delayed writes (write-back) on/off — bursty writer
        let wbw = |write_back: bool| -> Result<f64> {
            let mut cfg = bench_server_config(4 * MB, 0);
            cfg.cache.write_back = write_back;
            let pool = ServerPool::start(2, cfg)?;
            let mut c = pool.client()?;
            let h = c.open("ablw", OpenMode::rdwr_create())?;
            let chunk = vec![2u8; req as usize];
            let t0 = Instant::now();
            let mut off = 0;
            while off < file / 2 {
                c.write_at(h, off, &chunk)?;
                off += req;
            }
            c.sync(h)?;
            let el = t0.elapsed();
            pool.shutdown()?;
            Ok(mbps(file / 2, el))
        };
        let wb_on = wbw(true)?;
        let wb_off = wbw(false)?;
        print_table(
            "A2 ablation: delayed writes (write-back cache)",
            &["delayed writes", "write MB/s (incl. sync)"],
            &[
                vec!["on".into(), format!("{wb_on:.1}")],
                vec!["off (write-through)".into(), format!("{wb_off:.1}")],
            ],
        );

        // (c) request size sweep — seek/transfer crossover of the model
        let mut rows = Vec::new();
        for &rq in &[4 * 1024u64, 16 * 1024, 64 * 1024, 256 * 1024] {
            let r = vipios_shared_file(2, 2, file / 2, rq, MB, 0)?;
            rows.push(vec![
                crate::util::fmt_bytes(rq),
                format!("{:.1}", r.write_mbps),
                format!("{:.1}", r.read_mbps),
            ]);
        }
        print_table(
            "A3 ablation: request size (2 clients, 2 servers)",
            &["request", "write MB/s", "read MB/s"],
            &rows,
        );

        // (d) static fit: hinted BLOCK layout vs default cyclic heuristic
        let fit = |hinted: bool| -> Result<f64> {
            let pool = ServerPool::start(4, bench_server_config(MB, 0))?;
            {
                let mut c = pool.client()?;
                if hinted {
                    c.hint(Hint::FileAdmin(FileAdminHint {
                        name: "fit".into(),
                        distribution: Distribution::block_for(file, 4),
                        nprocs: Some(4),
                    }))?;
                }
                c.disconnect()?;
            }
            let r = {
                // 4 clients, each its quarter (as in E1) on this pool
                let per = file / 4;
                let barrier = Arc::new(Barrier::new(5));
                let done = Arc::new(Barrier::new(5));
                let mut hs = Vec::new();
                for i in 0..4usize {
                    let world = pool.world().clone();
                    let (barrier, done) = (barrier.clone(), done.clone());
                    hs.push(std::thread::spawn(move || -> Result<()> {
                        let mut c = Client::connect(&world)?;
                        let h = c.open("fit", OpenMode::rdwr_create())?;
                        let chunk = vec![1u8; 64 * 1024];
                        barrier.wait();
                        let mut off = i as u64 * per;
                        while off < (i as u64 + 1) * per {
                            c.write_at(h, off, &chunk)?;
                            off += 64 * 1024;
                        }
                        c.sync(h)?;
                        done.wait();
                        Ok(())
                    }));
                }
                barrier.wait();
                let t0 = Instant::now();
                done.wait();
                for h in hs {
                    h.join().unwrap()?;
                }
                let el = t0.elapsed();
                pool.shutdown()?;
                mbps(file, el)
            };
            Ok(r)
        };
        let hinted = fit(true)?;
        let heuristic = fit(false)?;
        print_table(
            "A4 ablation: hinted BLOCK layout (static fit) vs default heuristic",
            &["layout", "write MB/s"],
            &[
                vec!["hinted BLOCK (static fit)".into(), format!("{hinted:.1}")],
                vec!["default CYCLIC heuristic".into(), format!("{heuristic:.1}")],
            ],
        );
        Ok(())
    }

    /// E9 — async server kernel: aggregate cold-read bandwidth vs client
    /// concurrency × scheduler queue depth at fixed 2 servers × 2 disks
    /// (DESIGN.md §4.2). Queue depth 1 is the blocking baseline; the
    /// async engine must win by overlapping both spindles per server
    /// with message handling.
    pub fn overlap(quick: bool) -> Result<()> {
        let per_client = if quick { MB } else { 2 * MB };
        let req = 64 * 1024;
        let (nservers, ndisks) = (2, 2);
        let clients: Vec<usize> = if quick { vec![2, 8] } else { vec![1, 2, 4, 8] };
        let depths: Vec<usize> = if quick { vec![1, 8] } else { vec![1, 4, 16] };
        let mut rows = Vec::new();
        let mut at8: Vec<(usize, f64)> = Vec::new();
        for &nc in &clients {
            let mut row = vec![nc.to_string()];
            for &qd in &depths {
                let bw = overlap_bw(nc, nservers, ndisks, qd, per_client, req)?;
                row.push(format!("{bw:.1}"));
                if nc == 8 {
                    at8.push((qd, bw));
                }
            }
            rows.push(row);
        }
        let mut headers: Vec<String> = vec!["clients".into()];
        for &qd in &depths {
            headers.push(if qd <= 1 {
                "qd=1 (blocking)".to_string()
            } else {
                format!("qd={qd}")
            });
        }
        let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
        print_table(
            &format!(
                "E9 (§4.2) overlap — aggregate cold-read MB/s, {nservers} servers x {ndisks} disks"
            ),
            &hdr_refs,
            &rows,
        );
        // headline ratio: best async depth vs blocking at 8 clients
        let blocking = at8.iter().find(|(qd, _)| *qd <= 1).map(|&(_, bw)| bw);
        let best = at8
            .iter()
            .filter(|(qd, _)| *qd > 1)
            .map(|&(_, bw)| bw)
            .fold(f64::NAN, f64::max);
        if let Some(base) = blocking {
            if best.is_finite() && base > 0.0 {
                print_table(
                    "E9 summary — async kernel vs blocking baseline (8 clients)",
                    &["blocking MB/s", "async MB/s", "speedup"],
                    &[vec![
                        format!("{base:.1}"),
                        format!("{best:.1}"),
                        format!("{:.2}x", best / base),
                    ]],
                );
            }
        }
        Ok(())
    }

    /// E10 — §2/§3.2.2 access-pattern knowledge: strided cold reads with
    /// think time, hint-less vs online pattern detection vs a
    /// compiler-emitted access plan; plus the OOC Jacobi sweep with and
    /// without the plan-driven tile pipeline (DESIGN.md §4.3).
    pub fn prefetch(quick: bool) -> Result<()> {
        let total = if quick { 8 * MB } else { 32 * MB };
        let (blk, stride) = (64 * 1024u64, 256 * 1024u64);
        let think_us = 2000;
        let mut rows = Vec::new();
        let mut by_mode: Vec<(PrefetchMode, PrefetchRun)> = Vec::new();
        for (label, mode) in [
            ("off (hint-less)", PrefetchMode::Off),
            ("pattern (online detector)", PrefetchMode::Pattern),
            ("plan (AccessPlan hint)", PrefetchMode::Plan),
        ] {
            let r = prefetch_strided(mode, 2, total, blk, stride, think_us)?;
            rows.push(vec![
                label.to_string(),
                format!("{:.1}", r.mbps),
                format!("{:.1}%", r.hit_rate * 100.0),
                crate::util::fmt_bytes(r.predicted),
                r.wasted.to_string(),
            ]);
            by_mode.push((mode, r));
        }
        print_table(
            &format!(
                "E10 (§3.2.2) strided cold read + think time ({}  blk/stride {}K/{}K, 2 servers)",
                crate::util::fmt_bytes(total),
                blk / 1024,
                stride / 1024
            ),
            &["mode", "MB/s", "hit rate", "predicted", "wasted pages"],
            &rows,
        );
        let base = by_mode
            .iter()
            .find(|(m, _)| *m == PrefetchMode::Off)
            .map(|&(_, r)| r)
            .expect("off mode present");
        let mut urows = Vec::new();
        for (label, mode) in
            [("pattern", PrefetchMode::Pattern), ("plan", PrefetchMode::Plan)]
        {
            let r = by_mode
                .iter()
                .find(|(m, _)| *m == mode)
                .map(|&(_, r)| r)
                .expect("mode present");
            urows.push(vec![
                label.to_string(),
                format!("{:.2}x", r.mbps / base.mbps.max(1e-9)),
                format!("{:.1}", (r.hit_rate - base.hit_rate) * 100.0),
            ]);
        }
        print_table(
            "E10 summary — prefetch uplift vs hint-less async baseline",
            &["mode", "bandwidth uplift", "hit-rate uplift (points)"],
            &urows,
        );
        // OOC half: plan-driven tile pipeline through the compute backend
        let nb = if quick { 2 } else { 3 };
        let (bw_off, hit_off) = prefetch_ooc(false, nb)?;
        let (bw_plan, hit_plan) = prefetch_ooc(true, nb)?;
        print_table(
            &format!("E10 OOC Jacobi sweep ({nb}x{nb} blocks, cold, 2 servers)"),
            &["mode", "MB/s", "hit rate"],
            &[
                vec![
                    "no hints".into(),
                    format!("{bw_off:.1}"),
                    format!("{:.1}%", hit_off * 100.0),
                ],
                vec![
                    "plan-driven".into(),
                    format!("{bw_plan:.1}"),
                    format!("{:.1}%", hit_plan * 100.0),
                ],
            ],
        );
        Ok(())
    }

    /// E11 — §6.3.4 collective I/O: the E4c interleaved shape through
    /// ViMPIOS `read_at_all`, independent vs server-side aggregation
    /// (DESIGN.md §4.4), against ROMIO's client-side two-phase exchange
    /// on the same disk count. The amplification table shows the wire
    /// cost the list protocol saves.
    pub fn collective(quick: bool) -> Result<()> {
        let total = if quick { 4 * MB } else { 16 * MB };
        let (nprocs, nservers) = (4, 2);
        let ind = collective_read(nprocs, nservers, total, false)?;
        let coll = collective_read(nprocs, nservers, total, true)?;
        let tp = two_phase_romio(nservers, nprocs, total)?;
        print_table(
            &format!(
                "E11 (§6.3.4) collective interleaved read — {} file, {nprocs} procs, {nservers} servers (E4c shape)",
                crate::util::fmt_bytes(total)
            ),
            &["system", "MB/s"],
            &[
                vec![
                    "ViMPIOS independent (per-process + barrier)".into(),
                    format!("{:.1}", ind.mbps),
                ],
                vec![
                    "ViMPIOS collective (server-side aggregation)".into(),
                    format!("{:.1}", coll.mbps),
                ],
                vec!["ROMIO two-phase (client exchange)".into(), format!("{tp:.1}")],
            ],
        );
        print_table(
            "E11 message amplification — read phase (ER+DI over all servers)",
            &[
                "mode",
                "msgs",
                "list extents",
                "coalesced runs",
                "windows",
                "copied/demand",
                "aliased/demand",
            ],
            &[
                vec![
                    "independent".into(),
                    ind.msgs.to_string(),
                    ind.list_extents.to_string(),
                    ind.coalesced_runs.to_string(),
                    ind.windows.to_string(),
                    format!("{:.3}", ind.copied_per_byte()),
                    format!("{:.3}", ind.bytes_aliased as f64 / ind.demand.max(1) as f64),
                ],
                vec![
                    "collective".into(),
                    coll.msgs.to_string(),
                    coll.list_extents.to_string(),
                    coll.coalesced_runs.to_string(),
                    coll.windows.to_string(),
                    format!("{:.3}", coll.copied_per_byte()),
                    format!("{:.3}", coll.bytes_aliased as f64 / coll.demand.max(1) as f64),
                ],
            ],
        );
        print_table(
            "E11 summary — server-side aggregation vs two-phase baseline",
            &["two-phase MB/s", "collective MB/s", "speedup", "copied/demand"],
            &[vec![
                format!("{tp:.1}"),
                format!("{:.1}", coll.mbps),
                format!("{:.2}x", coll.mbps / tp.max(1e-9)),
                format!("{:.3}", coll.copied_per_byte()),
            ]],
        );
        Ok(())
    }

    /// Dispatch by experiment name.
    pub fn run(exp: &str, quick: bool) -> Result<()> {
        match exp {
            "dedicated" => dedicated(quick),
            "nondedicated" => nondedicated(quick),
            "vs_unix" => vs_unix(quick),
            "vs_romio" => vs_romio(quick),
            "scalability" => scalability(quick),
            "buffer" => buffer(quick),
            "redistribution" => redistribution(quick),
            "overlap" => overlap(quick),
            "prefetch" => prefetch(quick),
            "collective" => collective(quick),
            "ablation" => ablation(quick),
            // needs the deployment binaries built, so not part of "all"
            "deploy" => super::deploy::table(quick),
            // multi-minute wall clock even at --small, so not part of
            // "all" either — CI runs it as its own smoke job
            "tenants" => super::tenants::table(quick),
            "all" => {
                dedicated(quick)?;
                nondedicated(quick)?;
                vs_unix(quick)?;
                vs_romio(quick)?;
                scalability(quick)?;
                buffer(quick)?;
                redistribution(quick)?;
                overlap(quick)?;
                prefetch(quick)?;
                collective(quick)?;
                ablation(quick)
            }
            other => anyhow::bail!("unknown experiment '{other}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Small-size smoke tests: the benches proper run via `cargo bench`.
    const MB: u64 = 1 << 20;

    #[test]
    fn vipios_shared_file_smoke() {
        let r = vipios_shared_file(2, 2, 2 * MB, 64 * 1024, 8 * MB, 0).unwrap();
        assert!(r.write_mbps > 0.0 && r.read_mbps > 0.0);
    }

    #[test]
    fn baselines_smoke() {
        let u = unix_seq_file(MB, 64 * 1024).unwrap();
        assert!(u.write_mbps > 0.0);
        let h = host_centralized_file(2, MB, 64 * 1024).unwrap();
        assert!(h.read_mbps > 0.0);
        let r = contig_romio(2, MB, 64 * 1024).unwrap();
        assert!(r.read_mbps > 0.0);
    }

    #[test]
    fn strided_smoke() {
        let v = strided_vipios(2, MB, 4096, 8192).unwrap();
        assert!(v > 0.0);
        let r = strided_romio(2, MB, 4096, 8192).unwrap();
        assert!(r > 0.0);
    }

    #[test]
    fn cache_sweep_hit_rate_rises_with_capacity() {
        let (_bw_small, hit_small) = cache_sweep(4 * MB, MB, 2).unwrap();
        let (_bw_big, hit_big) = cache_sweep(4 * MB, 16 * MB, 2).unwrap();
        assert!(
            hit_big > hit_small,
            "hit rate should rise with cache: {hit_small} vs {hit_big}"
        );
    }

    #[test]
    fn two_phase_smoke() {
        let bw = two_phase_romio(2, 2, MB).unwrap();
        assert!(bw > 0.0);
    }

    #[test]
    fn redistribution_smoke() {
        let bw = redistribution_vipios(2, 2 * MB, 2).unwrap();
        assert!(bw > 0.0);
    }

    #[test]
    fn overlap_smoke() {
        // tiny: exercises both the blocking baseline and the async
        // engine end-to-end (ratio asserted in the nightly test below)
        let blocking = overlap_bw(2, 2, 2, 1, 256 * 1024, 64 * 1024).unwrap();
        let asynced = overlap_bw(2, 2, 2, 8, 256 * 1024, 64 * 1024).unwrap();
        assert!(blocking > 0.0 && asynced > 0.0);
    }

    /// E9 acceptance shape (nightly: timing-sensitive): at 8 clients on
    /// 2 servers x 2 disks, the async kernel must comfortably beat the
    /// blocking baseline. The bench table reports >= 2x; the assertion
    /// leaves margin for loaded CI machines.
    #[test]
    #[ignore]
    fn overlap_async_beats_blocking() {
        let blocking = overlap_bw(8, 2, 2, 1, 2 * MB, 64 * 1024).unwrap();
        let asynced = overlap_bw(8, 2, 2, 16, 2 * MB, 64 * 1024).unwrap();
        assert!(
            asynced >= 1.5 * blocking,
            "async {asynced:.1} MB/s vs blocking {blocking:.1} MB/s"
        );
    }

    #[test]
    fn prefetch_modes_smoke() {
        // tiny sizes: exercises all three modes end-to-end
        let off =
            prefetch_strided(PrefetchMode::Off, 2, MB, 64 * 1024, 128 * 1024, 100).unwrap();
        let pat =
            prefetch_strided(PrefetchMode::Pattern, 2, MB, 64 * 1024, 128 * 1024, 100).unwrap();
        let plan =
            prefetch_strided(PrefetchMode::Plan, 2, MB, 64 * 1024, 128 * 1024, 100).unwrap();
        assert!(off.mbps > 0.0 && pat.mbps > 0.0 && plan.mbps > 0.0);
        // kill-switch composition: the hint-less baseline predicts nothing
        assert_eq!(off.predicted, 0, "prefetch off must silence predictions");
        assert!(pat.predicted > 0, "detector never locked: {pat:?}");
        assert!(plan.predicted > 0, "plan never prefetched: {plan:?}");
    }

    /// E10 acceptance shape (nightly: timing-sensitive): pattern- and
    /// plan-driven prefetch must beat the hint-less async baseline by
    /// >= 1.3x aggregate cold-read bandwidth on the strided workload.
    #[test]
    #[ignore]
    fn prefetch_beats_hintless_baseline() {
        let total = 8 * MB;
        let off =
            prefetch_strided(PrefetchMode::Off, 2, total, 64 * 1024, 256 * 1024, 2000).unwrap();
        let pat = prefetch_strided(PrefetchMode::Pattern, 2, total, 64 * 1024, 256 * 1024, 2000)
            .unwrap();
        let plan =
            prefetch_strided(PrefetchMode::Plan, 2, total, 64 * 1024, 256 * 1024, 2000).unwrap();
        assert!(
            pat.mbps >= 1.3 * off.mbps,
            "pattern {:.1} MB/s vs off {:.1} MB/s",
            pat.mbps,
            off.mbps
        );
        assert!(
            plan.mbps >= 1.3 * off.mbps,
            "plan {:.1} MB/s vs off {:.1} MB/s",
            plan.mbps,
            off.mbps
        );
        assert!(
            pat.hit_rate > off.hit_rate + 0.3,
            "no hit-rate uplift: {:.2} vs {:.2}",
            pat.hit_rate,
            off.hit_rate
        );
    }

    #[test]
    fn prefetch_ooc_smoke() {
        let (bw, _hit) = prefetch_ooc(true, 2).unwrap();
        assert!(bw > 0.0);
    }

    #[test]
    fn json_report_records_tables() {
        crate::bench::report::reset();
        print_table(
            "t1",
            &["a", "b"],
            &[vec!["1.5".into(), "x\"y".into()]],
        );
        let json = crate::bench::report::to_json("unit", true);
        assert!(json.contains("\"experiment\":\"unit\""));
        assert!(json.contains("\"title\":\"t1\""));
        assert!(json.contains("[1.5,\"x\\\"y\"]"), "{json}");
        assert_eq!(crate::bench::report::tables().len(), 1);
    }

    #[test]
    fn redistribution_physical_smoke() {
        // both hops complete, verify byte-identical, and actually move
        // bytes across the two servers
        let hops = redistribution_physical(2, 2 * MB).unwrap();
        assert_eq!(hops.len(), 2);
        for h in &hops {
            assert!(h.bytes_moved > 0, "{}: nothing moved", h.label);
            assert!(h.di_msgs > 0, "{}: no DI traffic", h.label);
            assert!(h.shuffle_mbps > 0.0);
        }
    }

    #[test]
    fn collective_smoke() {
        // tiny: both modes end-to-end; the collective one must actually
        // aggregate (a window flushed, extents merged into fewer runs)
        let ind = collective_read(2, 2, MB, false).unwrap();
        let coll = collective_read(2, 2, MB, true).unwrap();
        assert!(ind.mbps > 0.0 && coll.mbps > 0.0);
        assert!(coll.windows >= 1, "no aggregation window flushed: {coll:?}");
        assert!(coll.list_extents >= 2, "{coll:?}");
        assert!(
            coll.coalesced_runs < coll.list_extents,
            "interleaved blocks must merge: {coll:?}"
        );
        // zero-copy acceptance: the read phase serves demand by aliasing
        // cache pages, not by flattening responses
        for r in [&ind, &coll] {
            assert!(
                r.copied_per_byte() <= 1.0,
                "read phase copied more than it served: {r:?}"
            );
            assert!(
                r.bytes_aliased >= r.demand,
                "demand not covered by aliased slices: {r:?}"
            );
        }
    }

    /// E11 acceptance shape (nightly: timing-sensitive): server-side
    /// aggregated `read_all` must beat the client-side two-phase
    /// baseline by >= 1.2x on the E4c interleaved shape.
    #[test]
    #[ignore]
    fn collective_beats_two_phase() {
        let total = 16 * MB;
        let coll = collective_read(4, 2, total, true).unwrap();
        let tp = two_phase_romio(2, 4, total).unwrap();
        assert!(
            coll.mbps >= 1.2 * tp,
            "collective {:.1} MB/s vs two-phase {:.1} MB/s",
            coll.mbps,
            tp
        );
    }
}
