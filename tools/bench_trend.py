#!/usr/bin/env python3
"""Aggregate `vipios bench --json` artifacts across runs into a per-cell
trend table (the ROADMAP "bench trajectory dashboards" item).

The CI perf-gate job uploads `BENCH_<exp>.json` per run. Download a set
of those artifacts (e.g. with `gh run download`) into one directory per
run, then:

    bench_trend.py runs/pr-101 runs/pr-102 runs/main-nightly
    bench_trend.py --glob 'runs/*' --out trend.md

Each positional argument is a *run*: a directory scanned recursively
for `BENCH_*.json`, or a single JSON file. Runs are labelled by their
basename and ordered as given (use shell sorting / --glob for
chronology). The output is a Markdown table per experiment table, one
row per gated-ish cell (same column heuristic as tools/perf_gate.py),
one column per run, so a drifting cell is visible before it trips the
gate floors.

Stdlib only; `--self-test` exercises the pipeline on synthetic data.
"""

import argparse
import glob as globlib
import json
import os
import re
import sys

# Same column heuristic as tools/perf_gate.py: the performance-shaped
# floors plus the ceiling cells — copies-per-byte and the E12/E13
# latency percentiles (lower is better there, but a drifting value is
# worth seeing either way).
TRACKED_HEADER = re.compile(
    r"MB/s|hit|speedup|uplift|rate|^qd=|copied/demand|copies/byte|p95|p99",
    re.IGNORECASE,
)

# Ceiling-shaped subset of TRACKED_HEADER: rendered with a "(↓ better)"
# marker so a falling trend line reads as the improvement it is.
CEILING_HEADER = re.compile(r"copied/demand|copies/byte|p95|p99", re.IGNORECASE)


def as_number(cell):
    if isinstance(cell, (int, float)):
        return float(cell)
    if isinstance(cell, str):
        t = cell.strip().rstrip("%x")
        try:
            return float(t)
        except ValueError:
            return None
    return None


def load_run(path):
    """Return {experiment: parsed-json} for one run (dir or file)."""
    files = []
    if os.path.isdir(path):
        for root, _dirs, names in os.walk(path):
            files.extend(
                os.path.join(root, n)
                for n in names
                if n.startswith("BENCH_") and n.endswith(".json")
            )
    elif os.path.isfile(path):
        files = [path]
    out = {}
    for f in sorted(files):
        try:
            with open(f, encoding="utf-8") as fh:
                doc = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping {f}: {e}", file=sys.stderr)
            continue
        out[doc.get("experiment", os.path.basename(f))] = doc
    return out


def cell_key(table_title, row_idx, header):
    return (table_title, row_idx, header)


def collect(runs):
    """runs: [(label, {exp: doc})] -> (ordered cell keys, {key: {label: value}})."""
    order = []
    values = {}
    for label, docs in runs:
        for exp in sorted(docs):
            for t in docs[exp].get("tables", []):
                headers = t.get("headers", [])
                cols = [i for i, h in enumerate(headers) if TRACKED_HEADER.search(h)]
                for ri, row in enumerate(t.get("rows", [])):
                    # first non-tracked cell labels the row, if any
                    for ci in cols:
                        if ci >= len(row):
                            continue
                        v = as_number(row[ci])
                        if v is None:
                            continue
                        key = cell_key(t["title"], ri, headers[ci])
                        if key not in values:
                            values[key] = {}
                            order.append(key)
                        values[key][label] = v
    return order, values


def row_label(docs_by_label, key):
    """Best-effort row label: the row's first cell (by convention the
    label column) in any run that has it."""
    title, ri, _ = key
    for docs in docs_by_label.values():
        for doc in docs.values():
            for t in doc.get("tables", []):
                if t["title"] != title:
                    continue
                rows = t.get("rows", [])
                if ri < len(rows) and rows[ri]:
                    return str(rows[ri][0])
    return f"row {ri}"


def render(labels, order, values, docs_by_label):
    if not order:
        # Well-formed empty report: a fresh branch whose runs carry no
        # artifacts yet must still yield valid Markdown (and exit 0),
        # not a zero-byte file that breaks downstream includes.
        return (
            "### bench trend\n\n"
            f"no `BENCH_*.json` artifacts across {len(labels)} run(s); "
            "nothing to trend yet.\n"
        )
    lines = []
    by_table = {}
    for key in order:
        by_table.setdefault(key[0], []).append(key)
    for title, keys in by_table.items():
        lines.append(f"### {title}\n")
        lines.append("| cell | " + " | ".join(labels) + " |")
        lines.append("|---|" + "---|" * len(labels))
        for key in keys:
            rl = row_label(docs_by_label, key)
            name = f"{rl} · {key[2]}"
            if CEILING_HEADER.search(key[2]):
                name += " (↓ better)"
            cells = []
            for lb in labels:
                v = values[key].get(lb)
                cells.append("—" if v is None else f"{v:g}")
            lines.append(f"| {name} | " + " | ".join(cells) + " |")
        lines.append("")
    return "\n".join(lines)


def self_test():
    mk = lambda bw, cpd: {
        "experiment": "overlap",
        "quick": True,
        "tables": [
            {
                "title": "t",
                "headers": ["clients", "MB/s", "note", "copied/demand"],
                "rows": [[8, bw, "x", cpd]],
            }
        ],
    }
    runs = [("r1", {"overlap": mk(10.0, 1.0)}), ("r2", {"overlap": mk(12.5, 0.002)})]
    order, values = collect(runs)
    assert len(order) == 2, order
    key = order[0]
    assert values[key] == {"r1": 10.0, "r2": 12.5}, values
    assert values[order[1]] == {"r1": 1.0, "r2": 0.002}, values
    docs_by_label = {lb: {"overlap": d["overlap"]} for lb, d in runs}
    md = render(["r1", "r2"], order, values, docs_by_label)
    assert "| 8 · MB/s | 10 | 12.5 |" in md, md
    # ceiling-shaped cells carry the direction marker, floors do not
    assert "| 8 · copied/demand (↓ better) | 1 | 0.002 |" in md, md
    assert "MB/s (↓ better)" not in md, md
    # mixed floor/ceiling table (the E13 shape): floors and percentile
    # ceilings from the same row each render with their own direction
    mixed = lambda bw, p99: {
        "experiment": "tenants",
        "quick": True,
        "tables": [
            {
                "title": "e13",
                "headers": ["class", "MB/s", "p50(us)", "p99(us)"],
                "rows": [["strided", bw, 900, p99]],
            }
        ],
    }
    runs_m = [("a", {"tenants": mixed(5.0, 12000)}), ("b", {"tenants": mixed(6.0, 3000)})]
    order_m, values_m = collect(runs_m)
    headers_m = [k[2] for k in order_m]
    assert headers_m == ["MB/s", "p99(us)"], headers_m  # p50 stays untracked
    docs_m = {lb: d for lb, d in runs_m}
    md_m = render(["a", "b"], order_m, values_m, docs_m)
    assert "| strided · MB/s | 5 | 6 |" in md_m, md_m
    assert "| strided · p99(us) (↓ better) | 12000 | 3000 |" in md_m, md_m
    # a run missing the cell renders a dash
    md2 = render(["r1", "r2", "r3"], order, values, docs_by_label)
    assert "| 10 | 12.5 | — |" in md2, md2
    # no artifacts at all -> well-formed empty report, not a blank file
    order0, values0 = collect([("r1", {})])
    assert (order0, values0) == ([], {}), (order0, values0)
    md0 = render(["r1"], order0, values0, {"r1": {}})
    assert md0.strip() and "nothing to trend" in md0, md0
    print("self-test OK")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("runs", nargs="*", help="run directories or BENCH_*.json files")
    ap.add_argument("--glob", help="shell glob adding runs (sorted)", default=None)
    ap.add_argument("--out", help="write Markdown here instead of stdout")
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()
    if args.self_test:
        return self_test()
    paths = list(args.runs)
    if args.glob:
        paths.extend(sorted(globlib.glob(args.glob)))
    if not paths:
        ap.error("no runs given (positional paths or --glob)")
    runs = []
    for p in paths:
        label = os.path.basename(os.path.normpath(p)) or p
        docs = load_run(p)
        if not docs:
            print(f"warning: no BENCH_*.json under {p}", file=sys.stderr)
        runs.append((label, docs))
    labels = [lb for lb, _ in runs]
    order, values = collect(runs)
    docs_by_label = {lb: docs for lb, docs in runs}
    md = render(labels, order, values, docs_by_label)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            fh.write(md)
        print(f"wrote {args.out}")
    else:
        print(md)
    return 0


if __name__ == "__main__":
    sys.exit(main())
