//! Fixture client: constructs every external request and waits on
//! every response — match arms, `if let` and `matches!` all count as
//! pattern position for the flow scan.

use crate::hints::{Hint, SystemHint};
use crate::msg::{Request, Response};

pub fn run(mut send: impl FnMut(Request), mut recv: impl FnMut() -> Response) {
    send(Request::Ping);
    send(Request::Read { off: 0, len: 4096 });
    send(Request::Hint(Hint::System(SystemHint::DropCaches)));
    loop {
        match recv() {
            Response::Pong => break,
            Response::Data(d) => drop(d),
            Response::Error(e) => panic!("{e}"),
        }
    }
    if let Response::Data(d) = recv() {
        assert!(!d.is_empty());
    }
    while matches!(recv(), Response::Pong) {
        // drain trailing acks
    }
    send(Request::Shutdown);
}
