//! Fixture server: one handler arm per request, every response
//! produced, the `cs.<field>` Stat fold-in convention, and an
//! allow-listed wall-clock read (the determinism lint's clean shape).

use crate::memory::CacheStats;
use crate::msg::{Request, Response, ServerStats};

pub fn handle(req: Request, stats: &mut ServerStats, cache: &Cache) -> Response {
    stats.requests += 1;
    match req {
        Request::Ping => Response::Pong,
        Request::Read { off, len } => {
            stats.bytes_read += len;
            Response::Data(read_at(off, len))
        }
        Request::Hint(h) => {
            drop(h);
            Response::Pong
        }
        Request::Shutdown => {
            let cs: CacheStats = cache.stats();
            let mut s = stats.clone();
            s.cache_hits = cs.hits;
            s.cache_misses = cs.misses;
            if s.requests == 0 {
                return Response::Error(String::from("no traffic"));
            }
            Response::Pong
        }
    }
}

pub fn deadline() -> std::time::Instant {
    // non-model path only; model runs pump via virtual timeouts
    #[allow(clippy::disallowed_methods)]
    let now = std::time::Instant::now();
    now
}
