//! Fixture hints: a nested enum family (outer tag + inner tag), the
//! shape that forces protolint's decode extraction to disambiguate
//! nested match expressions.

#[derive(Debug, Clone)]
pub enum Hint {
    Prefetch(PrefetchHint),
    System(SystemHint),
}

#[derive(Debug, Clone)]
pub enum PrefetchHint {
    Sequential { window: u64 },
    DelayedWrite { enable: bool },
}

#[derive(Debug, Clone)]
pub enum SystemHint {
    DropCaches,
    Prefetch(bool),
}
