//! Fixture: a miniature `msg.rs` for the protolint self-test. Shapes
//! mirror the real tree (payload variants, doc comments, a trailing
//! `#[cfg(test)]` module the scans must ignore).

use crate::hints::Hint;

/// External request surface.
#[derive(Debug, Clone)]
pub enum Request {
    Ping,
    Read { off: u64, len: u64 },
    Hint(Hint),
    Shutdown,
}

/// Server replies.
#[derive(Debug, Clone)]
pub enum Response {
    Pong,
    Data(Vec<u8>),
    Error(String),
}

/// Message payload.
#[derive(Debug, Clone)]
pub enum Body {
    Req(Request),
    Resp(Response),
    Timeout,
}

/// Delivery class.
#[derive(Debug, Clone, Copy)]
pub enum MsgClass {
    ER,
    ACK,
}

/// Per-server counters (wire-visible; declaration order is tag order).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServerStats {
    pub requests: u64,
    pub bytes_read: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl ServerStats {
    /// Single source of truth for the codec array lengths.
    pub const FIELD_COUNT: usize = 4;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructions_inside_tests_are_invisible_to_the_flow_scan() {
        // would otherwise count as a Pong producer outside server.rs
        let _ = Response::Pong;
        let _ = Request::Ping;
    }
}
