//! Fixture layout: the wire-visible distribution enum.

#[derive(Debug, Clone, Copy)]
pub enum Distribution {
    Contiguous,
    Cyclic { chunk: u64 },
}
