//! Fixture cache stats: every field here must be folded into the Stat
//! reply by server.rs (the `cs.<field>` convention).

#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}
