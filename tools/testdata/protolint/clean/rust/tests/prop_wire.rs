//! Fixture generators mirroring the real `tests/prop_wire.rs`: one
//! `rand_*` fn per wire enum, `pick % N` selection, every variant
//! reachable, every `ServerStats` field populated.

fn rand_request(r: &mut Rng, pick: u64) -> Request {
    match pick % 4 {
        0 => Request::Ping,
        1 => Request::Read { off: r.next(), len: r.next() },
        2 => Request::Hint(rand_hint(r)),
        _ => Request::Shutdown,
    }
}

fn rand_response(r: &mut Rng, pick: u64) -> Response {
    match pick % 3 {
        0 => Response::Pong,
        1 => Response::Data(vec![r.next() as u8]),
        _ => Response::Error(String::from("e")),
    }
}

fn rand_body(r: &mut Rng, pick: u64) -> Body {
    match pick % 3 {
        0 => Body::Req(rand_request(r, r.next())),
        1 => Body::Resp(rand_response(r, r.next())),
        _ => Body::Timeout,
    }
}

fn rand_class(r: &mut Rng) -> MsgClass {
    if r.next() & 1 == 0 {
        MsgClass::ER
    } else {
        MsgClass::ACK
    }
}

fn rand_hint(r: &mut Rng) -> Hint {
    match r.next() % 3 {
        0 => Hint::Prefetch(PrefetchHint::Sequential { window: r.next() }),
        1 => Hint::Prefetch(PrefetchHint::DelayedWrite { enable: true }),
        _ => Hint::System(if r.next() & 1 == 0 {
            SystemHint::DropCaches
        } else {
            SystemHint::Prefetch(true)
        }),
    }
}

fn rand_distribution(r: &mut Rng) -> Distribution {
    if r.next() & 1 == 0 {
        Distribution::Contiguous
    } else {
        Distribution::Cyclic { chunk: 64 }
    }
}

fn rand_frame(r: &mut Rng, pick: u64) -> Frame {
    match pick % 2 {
        0 => Frame::Msg { msg: vec![r.next() as u8] },
        _ => Frame::Bye,
    }
}

fn rand_stats(r: &mut Rng) -> ServerStats {
    ServerStats {
        requests: r.next(),
        bytes_read: r.next(),
        cache_hits: r.next(),
        cache_misses: r.next(),
    }
}
