//! Fixture codec: the real `wire.rs` shapes in miniature — declaration-
//! order tags, nested hint matches, a bare-integer `put_class` arm
//! body, a block decode arm, and `FIELD_COUNT`-sized stats arrays.

use crate::hints::{Hint, PrefetchHint, SystemHint};
use crate::layout::Distribution;
use crate::msg::{Body, MsgClass, Request, Response, ServerStats};

/// One unit on the wire.
#[derive(Debug, Clone)]
pub enum Frame {
    Msg { msg: Vec<u8> },
    Bye,
}

fn put_request(out: &mut Vec<u8>, req: &Request) {
    match req {
        Request::Ping => put_u32(out, 0),
        Request::Read { off, len } => {
            put_u32(out, 1);
            put_u64(out, *off);
            put_u64(out, *len);
        }
        Request::Hint(h) => {
            put_u32(out, 2);
            put_hint(out, h);
        }
        Request::Shutdown => put_u32(out, 9),
    }
}

fn put_response(out: &mut Vec<u8>, resp: &Response) {
    match resp {
        Response::Pong => put_u32(out, 0),
        Response::Data(d) => {
            put_u32(out, 1);
            put_bytes(out, d);
        }
        Response::Error(msg) => {
            put_u32(out, 2);
            put_str(out, msg);
        }
    }
}

fn put_body(out: &mut Vec<u8>, body: &Body) {
    match body {
        Body::Req(r) => {
            put_u8(out, 0);
            put_request(out, r);
        }
        Body::Resp(r) => {
            put_u8(out, 1);
            put_response(out, r);
        }
        Body::Timeout => put_u8(out, 2),
    }
}

fn put_class(out: &mut Vec<u8>, c: MsgClass) {
    put_u8(
        out,
        match c {
            MsgClass::ER => 0,
            MsgClass::ACK => 1,
        },
    );
}

fn put_hint(out: &mut Vec<u8>, h: &Hint) {
    match h {
        Hint::Prefetch(p) => {
            put_u32(out, 0);
            match p {
                PrefetchHint::Sequential { window } => {
                    put_u32(out, 0);
                    put_u64(out, *window);
                }
                PrefetchHint::DelayedWrite { enable } => {
                    put_u32(out, 1);
                    put_u8(out, u8::from(*enable));
                }
            }
        }
        Hint::System(s) => {
            put_u32(out, 1);
            match s {
                SystemHint::DropCaches => put_u32(out, 0),
                SystemHint::Prefetch(on) => {
                    put_u32(out, 1);
                    put_u8(out, u8::from(*on));
                }
            }
        }
    }
}

fn put_dist(out: &mut Vec<u8>, d: Distribution) {
    match d {
        Distribution::Contiguous => put_u32(out, 0),
        Distribution::Cyclic { chunk } => {
            put_u32(out, 1);
            put_u64(out, chunk);
        }
    }
}

/// The [`ServerStats`] counters in declaration order.
fn stats_fields(s: &ServerStats) -> [u64; ServerStats::FIELD_COUNT] {
    [s.requests, s.bytes_read, s.cache_hits, s.cache_misses]
}

pub fn encode_frame(frame: &Frame, out: &mut Vec<u8>) {
    match frame {
        Frame::Msg { msg } => {
            put_u8(out, 0);
            put_bytes(out, msg);
        }
        Frame::Bye => put_u8(out, 1),
    }
}

impl Cur<'_> {
    fn request(&mut self) -> Result<Request> {
        Ok(match self.u32()? {
            0 => Request::Ping,
            1 => Request::Read { off: self.u64()?, len: self.u64()? },
            2 => Request::Hint(self.hint()?),
            3 => {
                // block arm: the variant is built last, like the real
                // tree's LocalReadScatter arm
                self.drain();
                Request::Shutdown
            }
            t => return Err(bad("Request", t)),
        })
    }

    fn response(&mut self) -> Result<Response> {
        Ok(match self.u32()? {
            0 => Response::Pong,
            1 => Response::Data(self.bytes()?),
            2 => Response::Error(self.string()?),
            t => return Err(bad("Response", t)),
        })
    }

    fn body(&mut self) -> Result<Body> {
        match self.u8()? {
            0 => Ok(Body::Req(self.request()?)),
            1 => Ok(Body::Resp(self.response()?)),
            2 => Ok(Body::Timeout),
            t => Err(bad("Body", t)),
        }
    }

    fn class(&mut self) -> Result<MsgClass> {
        match self.u8()? {
            0 => Ok(MsgClass::ER),
            1 => Ok(MsgClass::ACK),
            t => Err(bad("MsgClass", t)),
        }
    }

    fn hint(&mut self) -> Result<Hint> {
        Ok(match self.u32()? {
            0 => Hint::Prefetch(match self.u32()? {
                0 => PrefetchHint::Sequential { window: self.u64()? },
                1 => PrefetchHint::DelayedWrite { enable: self.u8()? != 0 },
                t => return Err(bad("PrefetchHint", t)),
            }),
            1 => Hint::System(match self.u32()? {
                0 => SystemHint::DropCaches,
                1 => SystemHint::Prefetch(self.u8()? != 0),
                t => return Err(bad("SystemHint", t)),
            }),
            t => return Err(bad("Hint", t)),
        })
    }

    fn dist(&mut self) -> Result<Distribution> {
        Ok(match self.u32()? {
            0 => Distribution::Contiguous,
            1 => Distribution::Cyclic { chunk: self.u64()? },
            t => return Err(bad("Distribution", t)),
        })
    }

    fn stats(&mut self) -> Result<ServerStats> {
        let mut s = ServerStats::default();
        let fields: [&mut u64; ServerStats::FIELD_COUNT] = [
            &mut s.requests,
            &mut s.bytes_read,
            &mut s.cache_hits,
            &mut s.cache_misses,
        ];
        for f in fields {
            *f = self.u64()?;
        }
        Ok(s)
    }
}

pub fn decode_frame(buf: &[u8]) -> Result<Option<(Frame, usize)>> {
    let mut c = Cur { buf, pos: 0 };
    let frame = match c.u8()? {
        0 => Frame::Msg { msg: c.bytes()? },
        1 => Frame::Bye,
        t => return Err(bad("Frame", t)),
    };
    Ok(Some((frame, c.pos)))
}
