#!/usr/bin/env python3
"""Perf-regression gate over `vipios bench <exp> --small --json` output.

Compares the MB/s-, hit-rate- and speedup-shaped cells of a fresh
`BENCH_<exp>.json` against a checked-in baseline under
`bench/baselines/`. Baselines are *floors*: a cell fails only when it
drops below `baseline * (1 - tol)` — SimDisk timing is deterministic in
shape, but CI machines vary in absolute speed, so the committed floors
are conservative and the tolerance band stays tight on top of them.

Copies-per-byte cells (`copied/demand`) and latency-percentile cells
(`p95`/`p99`, including the E13 `p99 on/off` headline ratio) are the
exception: they are *ceilings* — fewer copies / lower tail latency is
better, so a cell fails when it rises above `baseline * (1 + tol)`. The
committed ceiling for the E11 read phase is 1.0 copied bytes per
demanded byte (the zero-copy acceptance bound); the E13 strided-class
headline ceiling is 0.7 (arbitration must cut the p99 tail by >= 2x
minus the tolerance band).

Matching is structural: tables by exact title, rows by index, columns by
header. A baseline table/row/cell missing from the current output is a
failure (a silently dropped bench must not pass the gate).

Usage:
    perf_gate.py --baseline bench/baselines/BENCH_buffer.json \
                 --current rust/BENCH_buffer.json [--tol 0.2]
    perf_gate.py --self-test

Regenerating a baseline after an intentional change:
    cargo run --release --bin vipios -- bench <exp> --small --json
    cp rust/BENCH_<exp>.json bench/baselines/   # then lower the floors
"""

import argparse
import json
import re
import sys

# Only performance-shaped columns are gated; counts, labels and byte
# totals are informational. `qd=` covers the E9 overlap matrix, whose
# MB/s unit lives in the table title.
GATED_HEADER = re.compile(r"MB/s|hit|speedup|uplift|rate|^qd=", re.IGNORECASE)

# Ceiling-gated columns: lower is better, fail when the current value
# exceeds baseline * (1 + tol). Latency percentiles auto-classify by
# header name (`p95(us)`, `p99(us)`, `p99 on/off`, ...). Must stay
# disjoint from GATED_HEADER.
CEILING_HEADER = re.compile(r"copied/demand|copies/byte|p95|p99", re.IGNORECASE)


def as_number(cell):
    """Parse a bench cell: JSON numbers pass through; strings like
    '93.3%' or '2.10x' are unwrapped. Returns None for non-numeric."""
    if isinstance(cell, (int, float)):
        return float(cell)
    if isinstance(cell, str):
        t = cell.strip().rstrip("%x")
        try:
            return float(t)
        except ValueError:
            return None
    return None


def compare(baseline, current, tol):
    """Return a list of failure strings (empty = gate passes)."""
    failures = []
    cur_tables = {t["title"]: t for t in current.get("tables", [])}
    for bt in baseline.get("tables", []):
        title = bt["title"]
        ct = cur_tables.get(title)
        if ct is None:
            failures.append(f"table missing from current output: {title!r}")
            continue
        headers = bt.get("headers", [])
        gated_cols = [
            (i, "floor" if GATED_HEADER.search(h) else "ceiling")
            for i, h in enumerate(headers)
            if GATED_HEADER.search(h) or CEILING_HEADER.search(h)
        ]
        for ri, brow in enumerate(bt.get("rows", [])):
            if ri >= len(ct.get("rows", [])):
                failures.append(f"{title!r}: row {ri} missing from current output")
                continue
            crow = ct["rows"][ri]
            for ci, kind in gated_cols:
                if ci >= len(brow):
                    continue
                bound = as_number(brow[ci])
                if bound is None:
                    continue  # non-numeric baseline cell: informational
                raw = crow[ci] if ci < len(crow) else "<missing>"
                got = as_number(raw)
                if got is None:
                    failures.append(
                        f"{title!r} row {ri} col {headers[ci]!r}: "
                        f"non-numeric current cell {raw!r}"
                    )
                    continue
                if kind == "floor":
                    limit = bound * (1.0 - tol)
                    bad = got < limit
                    rel, word = ("<", "floor") if bad else (">=", "floor")
                    detail = f"{got:.3g} {rel} {word} {bound:.3g} * (1 - {tol}) = {limit:.3g}"
                else:
                    limit = bound * (1.0 + tol)
                    bad = got > limit
                    rel, word = (">", "ceiling") if bad else ("<=", "ceiling")
                    detail = f"{got:.3g} {rel} {word} {bound:.3g} * (1 + {tol}) = {limit:.3g}"
                if bad:
                    failures.append(f"{title!r} row {ri} col {headers[ci]!r}: {detail}")
                else:
                    print(f"  ok: {title!r} row {ri} {headers[ci]!r}: {detail}")
    return failures


def self_test():
    base = {
        "tables": [
            {
                "title": "t",
                "headers": ["mode", "MB/s", "hit rate", "msgs", "copied/demand"],
                "rows": [["a", 100, "80.0%", 7, 1.0], ["b", 50, "10.0%", 9, 1.0]],
            },
            {
                "title": "lat",
                "headers": ["class", "MB/s", "p50(us)", "p95(us)", "p99(us)"],
                # p50 is informational (non-numeric baseline); p95/p99
                # are ceilings, MB/s stays a floor in the same row
                "rows": [["strided", 20, "-", 4000, 12000]],
            },
        ]
    }
    ok = {
        "tables": [
            {
                "title": "t",
                "headers": ["mode", "MB/s", "hit rate", "msgs", "copied/demand"],
                # faster + msgs column regressed (not gated) + fewer
                # copies (under the ceiling) -> pass
                "rows": [["a", 120, "85.0%", 900, 0.002], ["b", 45, "9.5%", 1, 1.1]],
            },
            {
                "title": "lat",
                "headers": ["class", "MB/s", "p50(us)", "p95(us)", "p99(us)"],
                # higher throughput AND lower tail -> both directions pass
                "rows": [["strided", 25, 999999, 1500, 3000]],
            },
        ]
    }
    assert compare(base, ok, 0.2) == [], "clean run must pass"
    bad = json.loads(json.dumps(ok))
    bad["tables"][0]["rows"][0][1] = 10  # MB/s collapsed
    fails = compare(base, bad, 0.2)
    assert len(fails) == 1 and "MB/s" in fails[0], f"regression not caught: {fails}"
    copious = json.loads(json.dumps(ok))
    copious["tables"][0]["rows"][0][4] = 3.0  # copies above the ceiling
    fails = compare(base, copious, 0.2)
    assert len(fails) == 1 and "copied/demand" in fails[0] and "ceiling" in fails[0], (
        f"copy regression not caught: {fails}"
    )
    # latency ceiling direction: a p99 above baseline*(1+tol) fails even
    # while the floor columns of the same row improve
    tail = json.loads(json.dumps(ok))
    tail["tables"][1]["rows"][0][4] = 20000
    fails = compare(base, tail, 0.2)
    assert len(fails) == 1 and "p99" in fails[0] and "ceiling" in fails[0], (
        f"tail-latency regression not caught: {fails}"
    )
    # and a p95 exactly at the bound passes while one above fails
    edge = json.loads(json.dumps(ok))
    edge["tables"][1]["rows"][0][3] = 4000 * 1.2
    assert compare(base, edge, 0.2) == [], "p95 at the ceiling must pass"
    edge["tables"][1]["rows"][0][3] = 4000 * 1.2 + 1
    fails = compare(base, edge, 0.2)
    assert len(fails) == 1 and "p95" in fails[0], f"p95 ceiling not enforced: {fails}"
    missing = {"tables": []}
    assert compare(base, missing, 0.2), "missing table must fail"
    nonnum = json.loads(json.dumps(ok))
    nonnum["tables"][0]["rows"][0][1] = "n/a"
    assert compare(base, nonnum, 0.2), "non-numeric current cell must fail"
    print("self-test ok")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline")
    ap.add_argument("--current")
    ap.add_argument("--tol", type=float, default=0.2)
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()
    if args.self_test:
        return self_test()
    if not args.baseline or not args.current:
        ap.error("--baseline and --current are required (or --self-test)")
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    print(f"perf gate: {args.current} vs floor {args.baseline} (tol {args.tol})")
    failures = compare(baseline, current, args.tol)
    if failures:
        print(f"\nPERF GATE FAILED ({len(failures)} cell(s)):", file=sys.stderr)
        for f_ in failures:
            print(f"  FAIL: {f_}", file=sys.stderr)
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
