#!/usr/bin/env python3
"""protolint — static drift detection over the ViPIOS wire protocol.

The message interface is maintained by hand in four places: the enum
declarations (`msg.rs`/`hints.rs`/`layout.rs`/`wire.rs`), the
declaration-order codec (`wire.rs`), the server dispatcher
(`server.rs`), and the fuzzer generators (`tests/prop_wire.rs`). The
prop_wire fuzzer and the model checker catch drift between them only
*dynamically*, for inputs they happen to generate; this tool proves the
representations agree on every variant, statically, before CI ever
compiles (the authoring environment has no Rust toolchain, so a
Python-checkable oracle is the first gate).

Check classes (each backed by a fixture under `tools/testdata/protolint/`
that injects the drift and asserts the lint fires — see `--self-test`):

  codec         every wire enum variant has exactly one encode arm and
                one decode tag, and the tag equals the declaration index
  stats         `stats_fields` / the stats decoder list every
                `ServerStats` field in declaration order, `FIELD_COUNT`
                matches, and every `CacheStats` field is folded into the
                `Request::Stat` reply (the `cs.<field>` convention)
  fuzz          every wire-visible variant appears in the prop_wire
                generators, and a generator's `pick % N` modulus can
                reach every variant
  flow          every `Request` has a server handler arm and a
                constructor somewhere; every `Response` is produced by
                the server and consumed (pattern-matched) somewhere;
                the committed PROTOCOL.md equals the regenerated graph
  determinism   no `Instant::now` / `SystemTime::now` / `thread::sleep`
                in model-checked modules outside the explicit allowlist
                (`#[allow(clippy::disallowed_methods)]` or a
                `protolint: allow-wallclock` marker on/just above the
                call line)

Parsing is a deliberately small Rust-lite extraction: comments and
string/char literals are blanked (newlines preserved), `#[cfg(test)]
mod … { … }` regions are stripped, and enums / struct fields / fn
bodies / match arms are recovered by brace matching. It is not a Rust
parser; conventions it relies on (tag literal is the first
`put_u8`/`put_u32` in an encode arm, the Stat fold-in binding is named
`cs`, generators live in `fn rand_*`) are documented in DESIGN.md §4.9.

Exit codes (shared convention with bench_trend.py / perf_gate.py):
  0  clean (or self-test passed)
  1  lint findings (or self-test failure)
  2  usage error (argparse)

Usage:
    protolint.py [--root DIR]          lint the tree (default: repo root)
    protolint.py --write-protocol      regenerate PROTOCOL.md in place
    protolint.py --self-test           run the fixture battery
"""

import argparse
import os
import re
import sys

TOOLS_DIR = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.dirname(TOOLS_DIR)

# role -> path relative to the tree root. Roles marked optional are
# skipped when the file is missing (the self-test fixture trees carry
# only the files a check class needs).
FILES = {
    "msg": "rust/src/msg.rs",
    "wire": "rust/src/wire.rs",
    "hints": "rust/src/hints.rs",
    "layout": "rust/src/layout.rs",
    "memory": "rust/src/memory.rs",
    "server": "rust/src/server.rs",
    "client": "rust/src/client.rs",
    "vimpios": "rust/src/vimpios.rs",
    "check": "rust/src/check.rs",
    "sched": "rust/src/sched.rs",
    "modes": "rust/src/modes.rs",
    "bench": "rust/src/bench.rs",
    "bin_server": "rust/src/bin/vipios_server.rs",
    "bin_client": "rust/src/bin/vipios_client.rs",
    "prop_wire": "rust/tests/prop_wire.rs",
    "protocol_md": "PROTOCOL.md",
}
REQUIRED = {"msg", "wire", "hints", "layout", "memory", "server", "client", "prop_wire"}

# (enum name, declaring role, encode fn, decode fn) — all codec fns live
# in wire.rs. To teach protolint a new wire enum, add a row here, a
# generator row to GENERATORS, and extend the self-test fixture tree.
ENUMS = [
    ("Request", "msg", "put_request", "request"),
    ("Response", "msg", "put_response", "response"),
    ("Body", "msg", "put_body", "body"),
    ("MsgClass", "msg", "put_class", "class"),
    ("Hint", "hints", "put_hint", "hint"),
    ("PrefetchHint", "hints", "put_hint", "hint"),
    ("SystemHint", "hints", "put_hint", "hint"),
    ("Distribution", "layout", "put_dist", "dist"),
    ("Frame", "wire", "encode_frame", "decode_frame"),
]

# enum -> prop_wire generator fn that must name every variant.
GENERATORS = [
    ("Request", "rand_request"),
    ("Response", "rand_response"),
    ("Body", "rand_body"),
    ("MsgClass", "rand_class"),
    ("Hint", "rand_hint"),
    ("PrefetchHint", "rand_hint"),
    ("SystemHint", "rand_hint"),
    ("Distribution", "rand_distribution"),
    ("Frame", "rand_frame"),
]

# message-flow scan set (roles; tests stripped before scanning)
FLOW_ROLES = [
    "client",
    "vimpios",
    "server",
    "check",
    "modes",
    "bench",
    "bin_server",
    "bin_client",
]

# determinism lint scan set: the model-checked modules (PR-6 virtual-time
# discipline — `cfg.model` runs must never consult the wall clock).
DETERMINISM_ROLES = ["server", "check", "sched", "memory"]
WALLCLOCK = re.compile(r"\b(Instant::now|SystemTime::now|thread::sleep)\s*\(")
ALLOW_TOKENS = ("allow(clippy::disallowed_methods)", "protolint: allow-wallclock")
ALLOW_WINDOW = 3  # marker may sit on the line or up to 3 lines above

PROTOCOL_HEADER = (
    "# ViPIOS wire protocol — message-flow graph\n"
    "\n"
    "Generated by `tools/protolint.py --write-protocol`; do not edit by\n"
    "hand. CI regenerates this table and fails (`flow: PROTOCOL.md is\n"
    "stale`) when the committed copy drifts from the source. Tags are\n"
    "declaration indices (the codec is declaration-ordered); file lists\n"
    "come from the static message-flow scan over non-test code.\n"
)


# --------------------------------------------------------------- parsing


def sanitize(src):
    """Blank comments and string/char literals (newlines preserved) so
    brace matching and regex extraction never see quoted text."""
    out = list(src)
    i, n = 0, len(src)

    def blank(a, b):
        for k in range(a, min(b, n)):
            if src[k] != "\n":
                out[k] = " "

    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""
        if c == "/" and nxt == "/":
            j = i
            while j < n and src[j] != "\n":
                j += 1
            blank(i, j)
            i = j
        elif c == "/" and nxt == "*":
            depth, j = 1, i + 2
            while j < n and depth:
                if src[j] == "/" and j + 1 < n and src[j + 1] == "*":
                    depth += 1
                    j += 2
                elif src[j] == "*" and j + 1 < n and src[j + 1] == "/":
                    depth -= 1
                    j += 2
                else:
                    j += 1
            blank(i, j)
            i = j
        elif c == "r" and (i == 0 or not (src[i - 1].isalnum() or src[i - 1] == "_")):
            m = re.match(r'r(#*)"', src[i:])
            if m:
                closer = '"' + m.group(1)
                j = src.find(closer, i + m.end())
                j = n if j < 0 else j + len(closer)
                blank(i + m.end(), j - len(closer))
                i = j
            else:
                i += 1
        elif c == '"':
            j = i + 1
            while j < n:
                if src[j] == "\\":
                    j += 2
                elif src[j] == '"':
                    break
                else:
                    j += 1
            blank(i + 1, j)
            i = j + 1
        elif c == "'":
            if nxt == "\\":
                j = i + 2
                while j < n and src[j] != "'":
                    j += 1
                blank(i + 1, j)
                i = j + 1
            elif i + 2 < n and src[i + 2] == "'" and nxt not in ("'", ""):
                out[i + 1] = " "  # 'x' char literal
                i += 3
            else:
                i += 1  # lifetime
        else:
            i += 1
    return "".join(out)


def match_brace(s, i):
    """`s[i]` is '{'; return index of its matching '}' (or len(s))."""
    depth = 0
    for j in range(i, len(s)):
        if s[j] == "{":
            depth += 1
        elif s[j] == "}":
            depth -= 1
            if depth == 0:
                return j
    return len(s)


def strip_tests(san):
    """Blank `#[cfg(test)] mod … { … }` regions (newlines preserved)."""
    out = san
    # Other attributes (e.g. a mod-level clippy allow) may sit between
    # the cfg gate and the mod keyword; comments are already blanked.
    for m in re.finditer(
        r"#\[cfg\(test\)\]\s*(?:#\[[^\]]*\]\s*)*(?:pub\s+)?mod\s+\w+\s*\{", san
    ):
        lo = san.index("{", m.start())
        hi = match_brace(san, lo)
        body = out[m.start() : hi + 1]
        out = out[: m.start()] + re.sub(r"[^\n]", " ", body) + out[hi + 1 :]
    return out


def enum_variants(san, name):
    """Variant names of `enum <name>` in declaration order, or None."""
    m = re.search(r"\benum\s+" + name + r"\b[^{;]*\{", san)
    if not m:
        return None
    lo = san.index("{", m.start())
    hi = match_brace(san, lo)
    body = san[lo + 1 : hi]
    variants = []
    for entry in split_depth0(body, ","):
        vm = re.match(r"\s*(?:#\[[^\]]*\]\s*)*(?:pub\s+)?([A-Za-z_]\w*)", entry)
        if vm:
            variants.append(vm.group(1))
    return variants


def struct_fields(san, name):
    """Field names of `struct <name>` in declaration order, or None."""
    m = re.search(r"\bstruct\s+" + name + r"\b[^{;]*\{", san)
    if not m:
        return None
    lo = san.index("{", m.start())
    hi = match_brace(san, lo)
    fields = []
    for entry in split_depth0(san[lo + 1 : hi], ","):
        fm = re.match(
            r"\s*(?:#\[[^\]]*\]\s*)*(?:pub(?:\([^)]*\))?\s+)?([A-Za-z_]\w*)\s*:",
            entry,
        )
        if fm:
            fields.append(fm.group(1))
    return fields


def split_depth0(s, sep):
    """Split on `sep` at bracket depth 0 (over (), [], {})."""
    parts, depth, start = [], 0, 0
    for j, c in enumerate(s):
        if c in "([{":
            depth += 1
        elif c in ")]}":
            depth -= 1
        elif c == sep and depth == 0:
            parts.append(s[start:j])
            start = j + 1
    parts.append(s[start:])
    return parts


def fn_body(san, name):
    """Body text of `fn <name>` (between its braces), or None."""
    m = re.search(r"\bfn\s+" + name + r"\b", san)
    if not m:
        return None
    i = san.index("(", m.end())
    depth = 0
    for j in range(i, len(san)):
        if san[j] == "(":
            depth += 1
        elif san[j] == ")":
            depth -= 1
            if depth == 0:
                break
    lo = san.index("{", j)
    hi = match_brace(san, lo)
    return san[lo + 1 : hi]


def match_regions(body):
    """(start, end) of every `match … { … }` arm region in `body`."""
    regions = []
    for m in re.finditer(r"\bmatch\b", body):
        lo = body.find("{", m.end())
        if lo < 0:
            continue
        regions.append((lo + 1, match_brace(body, lo)))
    return regions


def split_arms(s, base=0):
    """Split a match-arm region into (pat_lo, pat_hi, body_lo, body_hi)
    spans (offsets shifted by `base` so they index the enclosing text)."""
    arms = []
    i, n = 0, len(s)
    while i < n:
        while i < n and (s[i].isspace() or s[i] == ","):
            i += 1
        if i >= n:
            break
        depth, j = 0, i
        while j < n:
            c = s[j]
            if c in "([{":
                depth += 1
            elif c in ")]}":
                depth -= 1
            elif c == "=" and depth == 0 and j + 1 < n and s[j + 1] == ">":
                break
            j += 1
        if j >= n:
            break
        k = j + 2
        while k < n and s[k].isspace():
            k += 1
        if k < n and s[k] == "{":
            e = match_brace(s, k)
            arms.append((base + i, base + j, base + k, base + e + 1))
            i = e + 1
        else:
            depth, e = 0, k
            while e < n:
                c = s[e]
                if c in "([{":
                    depth += 1
                elif c in ")]}":
                    depth -= 1
                    if depth < 0:
                        break
                elif c == "," and depth == 0:
                    break
                e += 1
            arms.append((base + i, base + j, base + k, base + e))
            i = e + 1
    return arms


def variant_re(enum):
    # word-boundary lookbehind: `Hint::` must not match inside
    # `PrefetchHint::` / `SystemHint::` / `FileAdminHint`
    return re.compile(r"(?<![A-Za-z0-9_])" + enum + r"::([A-Za-z_]\w*)")


PUT_TAG = re.compile(r"\bput_u(?:8|32)\s*\(\s*\w+\s*,\s*(\d+)\b")


def encode_tags(body, enum):
    """variant -> tag from encode arms: the arm pattern names the
    variant; the tag is the first literal `put_u8`/`put_u32` in the arm
    body (or a bare-integer arm body, the `put_class` shape)."""
    tags, errs = {}, []
    vre = variant_re(enum)
    for lo, hi in match_regions(body):
        for plo, phi, blo, bhi in split_arms(body[lo:hi], lo):
            vm = vre.search(body[plo:phi])
            if not vm:
                continue
            variant = vm.group(1)
            abody = body[blo:bhi]
            pm = PUT_TAG.search(abody)
            if pm:
                tag = int(pm.group(1))
            else:
                bare = abody.strip().lstrip("{").rstrip("}").strip()
                if re.fullmatch(r"\d+", bare):
                    tag = int(bare)
                else:
                    errs.append(f"{enum}::{variant} encode arm has no literal tag")
                    continue
            if variant in tags and tags[variant] != tag:
                errs.append(
                    f"{enum}::{variant} encoded with conflicting tags "
                    f"{tags[variant]} and {tag}"
                )
            tags[variant] = tag
    return tags, errs


def decode_tags(body, enum):
    """tag -> variant from decode arms. Decoders nest (`fn hint` holds
    the Hint, PrefetchHint and SystemHint matches), so per enum we keep
    the match expression constructing the most distinct variants from
    integer-pattern arms."""
    vre = variant_re(enum)
    best = {}
    for lo, hi in match_regions(body):
        cand = {}
        for plo, phi, blo, bhi in split_arms(body[lo:hi], lo):
            pat = body[plo:phi].strip()
            if not re.fullmatch(r"\d+", pat):
                continue
            names = vre.findall(body[blo:bhi])
            if names:
                cand[int(pat)] = names[-1]  # block arms build the variant last
        if len(set(cand.values())) > len(set(best.values())):
            best = cand
    return best


def pattern_spans(san):
    """Spans of `san` that are pattern (not expression) position: match
    arm patterns, `let` / `if let` / `while let` left-hand sides, and
    `matches!` second arguments."""
    spans = []
    for lo, hi in match_regions(san):
        spans.extend((plo, phi) for plo, phi, _b, _e in split_arms(san[lo:hi], lo))
    for m in re.finditer(r"\blet\b", san):
        depth, j = 0, m.end()
        while j < len(san):
            c = san[j]
            if c in "([{":
                depth += 1
            elif c in ")]}":
                depth -= 1
                if depth < 0:
                    break
            elif c == "=" and depth == 0:
                if san[j + 1 : j + 2] not in (">", "=") and san[j - 1 : j] != "!":
                    break
            elif c == ";" and depth == 0:
                break
            j += 1
        spans.append((m.end(), j))
    for m in re.finditer(r"\bmatches!\s*[(\[]", san):
        lo = m.end() - 1
        close = {"(": ")", "[": "]"}[san[lo]]
        depth, comma = 0, None
        for j in range(lo, len(san)):
            c = san[j]
            if c in "([{":
                depth += 1
            elif c in ")]}":
                depth -= 1
                if depth == 0:
                    if comma is not None:
                        spans.append((comma + 1, j))
                    break
            elif c == "," and depth == 1 and comma is None:
                comma = j
    return spans


def classify_uses(san, enum):
    """(constructed, matched) variant-name sets for `enum` in `san`."""
    spans = pattern_spans(san)
    constructed, matched = set(), set()
    for m in variant_re(enum).finditer(san):
        in_pattern = any(lo <= m.start() < hi for lo, hi in spans)
        (matched if in_pattern else constructed).add(m.group(1))
    return constructed, matched


# ---------------------------------------------------------------- checks


class Tree:
    """Lazy loader: original text, sanitized text, and sanitized text
    with `#[cfg(test)]` modules stripped, per role. `overlay` (a second
    root whose files win) lets the self-test inject one drifted file
    over the clean fixture tree."""

    def __init__(self, root, overlay=None):
        self.root = root
        self.overlay = overlay
        self._raw, self._san, self._notest = {}, {}, {}

    def path(self, role):
        rel = FILES[role]
        if self.overlay:
            p = os.path.join(self.overlay, rel)
            if os.path.exists(p):
                return p
        return os.path.join(self.root, rel)

    def raw(self, role):
        if role not in self._raw:
            p = self.path(role)
            self._raw[role] = (
                open(p, encoding="utf-8").read() if os.path.exists(p) else None
            )
        return self._raw[role]

    def san(self, role):
        if role not in self._san:
            raw = self.raw(role)
            self._san[role] = None if raw is None else sanitize(raw)
        return self._san[role]

    def notest(self, role):
        if role not in self._notest:
            san = self.san(role)
            self._notest[role] = None if san is None else strip_tests(san)
        return self._notest[role]


def check_codec(tree):
    errs = []
    wire = tree.san("wire")
    for enum, role, efn, dfn in ENUMS:
        decl = enum_variants(tree.san(role), enum)
        if decl is None:
            errs.append(f"codec: enum {enum} not found in {FILES[role]}")
            continue
        ebody = fn_body(wire, efn)
        dbody = fn_body(wire, dfn)
        if ebody is None or dbody is None:
            errs.append(f"codec: fn {efn} / {dfn} not found in wire.rs")
            continue
        enc, eerrs = encode_tags(ebody, enum)
        errs.extend(f"codec: {e}" for e in eerrs)
        dec = decode_tags(dbody, enum)
        for idx, v in enumerate(decl):
            if v not in enc:
                errs.append(f"codec: {enum}::{v} has no encode arm in {efn}")
            elif enc[v] != idx:
                errs.append(
                    f"codec: {enum}::{v} encodes tag {enc[v]}, "
                    f"declaration index is {idx}"
                )
            if idx not in dec:
                errs.append(f"codec: {enum}::{v} (tag {idx}) has no decode arm in {dfn}")
            elif dec[idx] != v:
                errs.append(
                    f"codec: {dfn} decodes tag {idx} as {enum}::{dec[idx]}, "
                    f"declaration says {v}"
                )
        for v in sorted(set(enc) - set(decl)):
            errs.append(f"codec: {efn} encodes unknown variant {enum}::{v}")
        for t in sorted(set(dec) - set(range(len(decl)))):
            errs.append(f"codec: {dfn} decodes spurious tag {t} as {enum}::{dec[t]}")
    return errs


def check_stats(tree):
    errs = []
    fields = struct_fields(tree.san("msg"), "ServerStats")
    if fields is None:
        return ["stats: struct ServerStats not found in msg.rs"]
    wire = tree.san("wire")

    fc = re.search(r"\bconst\s+FIELD_COUNT\s*:\s*usize\s*=\s*(\d+)", tree.san("msg"))
    if not fc:
        errs.append("stats: ServerStats::FIELD_COUNT const not found in msg.rs")
    elif int(fc.group(1)) != len(fields):
        errs.append(
            f"stats: ServerStats::FIELD_COUNT = {fc.group(1)} but the struct "
            f"declares {len(fields)} fields"
        )

    for fname, pat in (("stats_fields", r"\bs\.(\w+)"), ("stats", r"&\s*mut\s+s\.(\w+)")):
        body = fn_body(wire, fname)
        if body is None:
            errs.append(f"stats: fn {fname} not found in wire.rs")
            continue
        order = re.findall(pat, body)
        if order != fields:
            errs.append(
                f"stats: {fname} field order diverges from the ServerStats "
                f"declaration: {diff_order(fields, order)}"
            )
        # array lengths must come from the shared const (or equal it)
        for alen in re.findall(
            r"\[\s*(?:&\s*mut\s+)?u64\s*;\s*([^\]]+)\]", body_sig(wire, fname)
        ):
            expr = alen.strip()
            if expr.isdigit() and int(expr) != len(fields):
                errs.append(
                    f"stats: {fname} array length {expr} != {len(fields)} fields "
                    "(use ServerStats::FIELD_COUNT)"
                )

    cfields = struct_fields(tree.san("memory"), "CacheStats")
    if cfields is None:
        errs.append("stats: struct CacheStats not found in memory.rs")
    else:
        folded = set(re.findall(r"\bcs\.(\w+)", tree.notest("server")))
        for f in cfields:
            if f not in folded:
                errs.append(
                    f"stats: CacheStats.{f} is never folded into the Stat reply "
                    f"(no `cs.{f}` read in server.rs)"
                )
    return errs


def body_sig(wire, fname):
    """fn signature + body text (array-length annotations live in both)."""
    m = re.search(r"\bfn\s+" + fname + r"\b", wire)
    if not m:
        return ""
    lo = wire.index("{", m.end())
    return wire[m.start() : match_brace(wire, lo)]


def diff_order(want, got):
    missing = [f for f in want if f not in got]
    extra = [f for f in got if f not in want]
    if missing or extra:
        return f"missing {missing or '[]'}, unknown {extra or '[]'}"
    for i, (w, g) in enumerate(zip(want, got)):
        if w != g:
            return f"position {i} is {g}, declaration says {w}"
    return f"{len(got)} fields vs {len(want)} declared"


def check_fuzz(tree):
    errs = []
    prop = tree.san("prop_wire")
    for enum, gen in GENERATORS:
        role = next(r for e, r, _ef, _df in ENUMS if e == enum)
        decl = enum_variants(tree.san(role), enum)
        if decl is None:
            continue  # codec check already reported the missing enum
        body = fn_body(prop, gen)
        if body is None:
            errs.append(f"fuzz: generator fn {gen} not found in prop_wire.rs")
            continue
        vre = variant_re(enum)
        present = set(vre.findall(body))
        for v in decl:
            if v not in present:
                errs.append(f"fuzz: {gen} never generates {enum}::{v}")
        for mod_ in re.findall(r"\bpick\s*%\s*(\d+)", body):
            if int(mod_) < len(decl):
                errs.append(
                    f"fuzz: {gen} selects with `pick % {mod_}` but {enum} has "
                    f"{len(decl)} variants — new variants are unreachable"
                )
    sfields = struct_fields(tree.san("msg"), "ServerStats")
    body = fn_body(prop, "rand_stats")
    if body is None:
        errs.append("fuzz: generator fn rand_stats not found in prop_wire.rs")
    elif sfields:
        for f in sfields:
            if not re.search(r"\b" + f + r"\s*:", body):
                errs.append(f"fuzz: rand_stats never populates ServerStats.{f}")
    return errs


def flow_scan(tree):
    """{enum: {variant: (constructed-in, matched-in file lists)}} over
    the non-test flow scan set."""
    uses = {"Request": {}, "Response": {}}
    for role in FLOW_ROLES:
        san = tree.notest(role)
        if san is None:
            continue
        short = os.path.basename(FILES[role])
        for enum in uses:
            constructed, matched = classify_uses(san, enum)
            for v in constructed:
                uses[enum].setdefault(v, (set(), set()))[0].add(short)
            for v in matched:
                uses[enum].setdefault(v, (set(), set()))[1].add(short)
    return uses


def check_flow(tree, protocol_out=None):
    errs = []
    uses = flow_scan(tree)
    requests = enum_variants(tree.san("msg"), "Request") or []
    responses = enum_variants(tree.san("msg"), "Response") or []
    for v in requests:
        constructed, matched = uses["Request"].get(v, (set(), set()))
        if "server.rs" not in matched:
            errs.append(f"flow: Request::{v} has no handler arm in server.rs")
        if not constructed:
            errs.append(f"flow: Request::{v} is never constructed (dead variant?)")
    for v in responses:
        constructed, matched = uses["Response"].get(v, (set(), set()))
        if "server.rs" not in constructed:
            errs.append(f"flow: Response::{v} is never produced by server.rs")
        if not matched:
            errs.append(f"flow: Response::{v} is never consumed (no wait arm)")

    generated = render_protocol(tree, uses, requests, responses)
    if protocol_out is not None:
        protocol_out.append(generated)
    committed = tree.raw("protocol_md")
    if committed is None:
        errs.append("flow: PROTOCOL.md is missing — run protolint.py --write-protocol")
    elif committed != generated:
        errs.append(
            "flow: PROTOCOL.md is stale — run `python3 tools/protolint.py "
            "--write-protocol` and commit the result"
        )
    return errs


def render_protocol(tree, uses, requests, responses):
    def filelist(s):
        return ", ".join(sorted(s)) if s else "—"

    lines = [PROTOCOL_HEADER]
    lines.append("## Requests\n")
    lines.append("| tag | `Request::` | constructed in | handled in |")
    lines.append("|---:|---|---|---|")
    for i, v in enumerate(requests):
        c, m = uses["Request"].get(v, (set(), set()))
        lines.append(f"| {i} | {v} | {filelist(c)} | {filelist(m)} |")
    lines.append("\n## Responses\n")
    lines.append("| tag | `Response::` | produced in | consumed in |")
    lines.append("|---:|---|---|---|")
    for i, v in enumerate(responses):
        c, m = uses["Response"].get(v, (set(), set()))
        lines.append(f"| {i} | {v} | {filelist(c)} | {filelist(m)} |")
    lines.append("\n## Auxiliary wire enums (tag = declaration index)\n")
    lines.append("| enum | variants (in tag order) |")
    lines.append("|---|---|")
    for enum, role, _ef, _df in ENUMS:
        if enum in ("Request", "Response"):
            continue
        decl = enum_variants(tree.san(role), enum) or []
        lines.append(f"| `{enum}` | {', '.join(decl)} |")
    return "\n".join(lines) + "\n"


def check_determinism(tree):
    errs = []
    for role in DETERMINISM_ROLES:
        san = tree.notest(role)
        if san is None:
            continue
        raw_lines = tree.raw(role).splitlines()
        for ln, line in enumerate(san.splitlines()):
            m = WALLCLOCK.search(line)
            if not m:
                continue
            window = raw_lines[max(0, ln - ALLOW_WINDOW) : ln + 1]
            if any(tok in w for w in window for tok in ALLOW_TOKENS):
                continue
            errs.append(
                f"determinism: {FILES[role]}:{ln + 1}: {m.group(1)} in a "
                f"model-checked module outside the allowlist: "
                f"`{raw_lines[ln].strip()}`"
            )
    return errs


def run_checks(root, overlay=None, protocol_out=None):
    tree = Tree(root, overlay)
    missing = [FILES[r] for r in sorted(REQUIRED) if tree.raw(r) is None]
    if missing:
        return [f"usage: required file missing under {root}: {p}" for p in missing]
    errs = []
    errs += check_codec(tree)
    errs += check_stats(tree)
    errs += check_fuzz(tree)
    errs += check_flow(tree, protocol_out)
    errs += check_determinism(tree)
    return errs


def write_protocol(root):
    tree = Tree(root)
    uses = flow_scan(tree)
    requests = enum_variants(tree.san("msg"), "Request") or []
    responses = enum_variants(tree.san("msg"), "Response") or []
    text = render_protocol(tree, uses, requests, responses)
    path = os.path.join(root, FILES["protocol_md"])
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(text)
    print(f"wrote {path}")
    return 0


# ------------------------------------------------------------- self-test

# (overlay dir, check class that must fire, substring the finding must
# carry). Other classes may fire too — drift is rarely isolated — but
# the named class must report the named symptom.
DRIFT_CASES = [
    ("drift_codec", "codec:", "Shutdown"),
    ("drift_stats", "stats:", "stats_fields"),
    ("drift_fuzz", "fuzz:", "rand_request"),
    ("drift_flow", "flow:", "handler arm"),
    ("drift_protocol", "flow:", "stale"),
    ("drift_determinism", "determinism:", "Instant::now"),
]


def self_test():
    base = os.path.join(TOOLS_DIR, "testdata", "protolint")
    clean = os.path.join(base, "clean")
    errs = run_checks(clean)
    if errs:
        print("self-test FAILED: clean fixture tree must lint clean:", file=sys.stderr)
        for e in errs:
            print(f"  {e}", file=sys.stderr)
        return 1
    failed = False
    for overlay, cls, needle in DRIFT_CASES:
        errs = run_checks(clean, overlay=os.path.join(base, overlay))
        hits = [e for e in errs if e.startswith(cls) and needle in e]
        if not hits:
            failed = True
            print(
                f"self-test FAILED: {overlay} did not raise a {cls!r} finding "
                f"containing {needle!r}; got: {errs}",
                file=sys.stderr,
            )
        else:
            print(f"  {overlay}: fired {hits[0]}")
    if failed:
        return 1
    print(f"protolint self-test OK ({len(DRIFT_CASES)} drift fixtures, 5 check classes)")
    return 0


def main():
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--root", default=REPO_ROOT, help="tree root (default: repo root)")
    ap.add_argument(
        "--write-protocol",
        action="store_true",
        help="regenerate <root>/PROTOCOL.md from the flow scan and exit",
    )
    ap.add_argument("--self-test", action="store_true", help="run the fixture battery")
    args = ap.parse_args()
    if args.self_test:
        return self_test()
    if args.write_protocol:
        return write_protocol(args.root)
    errs = run_checks(args.root)
    if errs:
        print(f"protolint: {len(errs)} finding(s):", file=sys.stderr)
        for e in errs:
            print(f"  {e}", file=sys.stderr)
        return 1
    n_enums = len(ENUMS)
    print(f"protolint OK ({n_enums} wire enums, 5 check classes)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
