//! ViMPIOS demo — the paper's Chapter-6 MPI-IO examples, runnable:
//! derived datatypes, file views (Fig 6.4/6.5), explicit offsets,
//! non-blocking ops, a 3-process collective partition of a matrix by
//! complementary views, and the scatter-gather list API (DESIGN.md
//! §4.4) the viewed and collective paths now ride on: a viewed access
//! resolves client-side and crosses the wire as one `ReadList`/
//! `WriteList` per request, and `read_all` aggregates the group's
//! sub-requests server-side before any disk is touched.
//!
//! Run: `cargo run --release --example mpiio_views`

use vipios::modes::ServerPool;
use vipios::server::ServerConfig;
use vipios::vimpios::{
    open_all, Amode, Basic, ClientGroup, Datatype, MpiFile, Whence,
};

fn ints(v: &[u32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn from_ints(b: &[u8]) -> Vec<u32> {
    b.chunks(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect()
}

fn main() -> anyhow::Result<()> {
    let pool = ServerPool::start(2, ServerConfig::default())?;
    let int = Datatype::Basic(Basic::Int);

    // --- Fig 6.4: single process reads every 3rd int through a view ---
    {
        let mut c = pool.client()?;
        let mut f = MpiFile::open(&mut c, "fig64", Amode::rdwr_create())?;
        let data: Vec<u32> = (0..24).collect();
        f.write(&mut c, &ints(&data), 24, &int)?;
        let filetype = Datatype::vector(1, 1, 3, int.clone());
        f.set_view(&mut c, 0, int.clone(), filetype)?;
        let mut buf = vec![0u8; 8 * 4];
        f.seek(&mut c, 0, Whence::Set)?;
        f.read(&mut c, &mut buf, 8, &int)?;
        println!("Fig 6.4 every-3rd view: {:?}", from_ints(&buf));
        assert_eq!(from_ints(&buf), vec![0, 3, 6, 9, 12, 15, 18, 21]);
        f.close(&mut c)?;
    }

    // --- §6.2.4: explicit offsets + non-blocking with MPIO_Wait ---
    {
        let mut c = pool.client()?;
        let mut f = MpiFile::open(&mut c, "nb", Amode::rdwr_create())?;
        let data: Vec<u32> = (0..100).collect();
        f.write(&mut c, &ints(&data), 100, &int)?;
        f.set_view(&mut c, 0, int.clone(), int.clone())?;
        f.seek(&mut c, 0, Whence::Set)?;
        let r1 = f.iread(&mut c, 10, &int)?; // pos 0..10
        let r2 = f.iread(&mut c, 10, &int)?; // pos 10..20
        let mut b1 = vec![0u8; 40];
        let mut b2 = vec![0u8; 40];
        f.wait(&mut c, r1, Some(&mut b1))?;
        f.wait(&mut c, r2, Some(&mut b2))?;
        let mut b3 = vec![0u8; 40];
        f.read_at(&mut c, 51, &mut b3, 10, &int)?; // explicit offset
        println!(
            "buf1[0]={} buf2[0]={} buf3[0]={} pos={}",
            from_ints(&b1)[0],
            from_ints(&b2)[0],
            from_ints(&b3)[0],
            f.position(&c)?
        );
        assert_eq!(f.position(&c)?, 20); // read_at did not move the pointer
        f.close(&mut c)?;
    }

    // --- Fig 6.5: three processes with complementary views ---
    {
        let mut c0 = pool.client()?;
        let mut f = MpiFile::open(&mut c0, "fig65", Amode::rdwr_create())?;
        let data: Vec<u32> = (0..30).collect();
        f.write(&mut c0, &ints(&data), 30, &int)?;
        f.sync(&mut c0)?;
        f.close(&mut c0)?;

        let group = ClientGroup::new(3);
        let mut handles = Vec::new();
        for rank in 0..3usize {
            let member = group.member(rank);
            let world = pool.world().clone();
            handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<u32>> {
                let int = Datatype::Basic(Basic::Int);
                let mut c = vipios::client::Client::connect(&world)?;
                let mut f = MpiFile::open(&mut c, "fig65", Amode::rdonly())?;
                let ft = Datatype::vector(1, 1, 3, int.clone());
                f.set_view(&mut c, rank as u64 * 4, int.clone(), ft)?;
                let mut buf = vec![0u8; 40];
                member.read_all(&mut f, &mut c, &mut buf, 10, &int)?;
                Ok(from_ints(&buf))
            }));
        }
        let mut all = Vec::new();
        for (rank, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap()?;
            println!("Fig 6.5 process {rank}: {got:?}");
            all.extend(got);
        }
        all.sort_unstable();
        assert_eq!(all, (0..30).collect::<Vec<u32>>());
    }

    // --- DESIGN.md §4.4: the scatter-gather list API, directly ---
    {
        let mut c = pool.client()?;
        let h = c.open("listio", vipios::msg::OpenMode::rdwr_create())?;
        // one message writes two runs with a hole between them ...
        let head = ints(&(0..8).collect::<Vec<_>>());
        let tail = ints(&(100..108).collect::<Vec<_>>());
        c.write_list(h, &[(0, head.as_slice()), (256, tail.as_slice())])?;
        // ... and one message gathers them back, out of order
        let mut buf = vec![0u8; 64];
        let n = c.read_list(h, &[(256, 32), (0, 32)], &mut buf)?;
        assert_eq!(n, 64);
        let got = from_ints(&buf);
        println!("list gather (tail first): {got:?}");
        assert_eq!(&got[..8], &(100..108).collect::<Vec<u32>>()[..]);
        assert_eq!(&got[8..], &(0..8).collect::<Vec<u32>>()[..]);
        c.close(h)?;
    }

    // --- §6.3.6: subarray — read a 3x4 tile out of an 8x8 matrix ---
    {
        let mut clients = vec![pool.client()?];
        let mut files = open_all(&mut clients, "matrix", Amode::rdwr_create())?;
        let (c, f) = (&mut clients[0], &mut files[0]);
        let data: Vec<u32> = (0..64).collect();
        f.write(c, &ints(&data), 64, &int)?;
        let sub = Datatype::subarray2((8, 8), (3, 4), (2, 1), int.clone())?;
        f.set_view(c, 0, int.clone(), sub)?;
        f.seek(c, 0, Whence::Set)?;
        let mut buf = vec![0u8; 12 * 4];
        f.read(c, &mut buf, 12, &int)?;
        let tile = from_ints(&buf);
        println!("subarray tile: {tile:?}");
        assert_eq!(
            tile,
            vec![17, 18, 19, 20, 25, 26, 27, 28, 33, 34, 35, 36]
        );
    }

    pool.shutdown()?;
    println!("mpiio_views OK");
    Ok(())
}
