//! HPF interface (Chapter 7): SPMD processes doing FORTRAN-style I/O on
//! a distributed array — `!HPF$ DISTRIBUTE A(BLOCK, CYCLIC(2)) ONTO P(2,2)`.
//!
//! Each of the four processes writes exactly the elements it owns; the
//! file holds the canonical row-major array image; a sequential process
//! (e.g. a post-processing tool) then reads it back linearly — the
//! paper's promise that the physical/SPMD distribution is invisible in
//! the persistent file.
//!
//! Run: `cargo run --release --example hpf_arrays`

use vipios::hpf::{read_local, write_local, ArrayDesc, Dist};
use vipios::modes::ServerPool;
use vipios::msg::OpenMode;
use vipios::server::ServerConfig;

const N: u32 = 16; // global array is N x N ints

fn main() -> anyhow::Result<()> {
    let pool = ServerPool::start(4, ServerConfig::default())?;

    // !HPF$ DISTRIBUTE A(BLOCK, CYCLIC(2)) ONTO P(2,2)
    let a = ArrayDesc::new(
        &[N, N],
        &[Dist::Block, Dist::Cyclic(2)],
        &[2, 2],
        4,
    )?;
    println!(
        "A({N},{N}) ints, DISTRIBUTE (BLOCK, CYCLIC(2)) ONTO P(2,2); \
         local sizes: {:?}",
        (0..4).map(|r| a.local_elems(r)).collect::<Vec<_>>()
    );

    // SPMD phase: every process writes its owned elements; value = the
    // global linear index, so the file image is self-checking.
    let mut handles = Vec::new();
    for rank in 0..4u32 {
        let world = pool.world().clone();
        let a = a.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let mut c = vipios::client::Client::connect(&world)?;
            let h = c.open("A.dat", OpenMode::rdwr_create())?;
            // compiler-generated: enumerate owned global indices in
            // row-major order and write their values
            let view = a.local_view(rank)?;
            let n = a.local_elems(rank);
            // recover the owned indices from the view itself
            let extents = view.resolve(0, 0, n * 4);
            let mut data = Vec::with_capacity((n * 4) as usize);
            for (off, len) in extents {
                for i in 0..len / 4 {
                    let gidx = off / 4 + i;
                    data.extend_from_slice(&(gidx as u32).to_le_bytes());
                }
            }
            write_local(&mut c, h, &a, rank, 0, &data)?;
            c.sync(h)?;
            println!("  rank {rank}: wrote {n} elements through its HPF view");
            c.disconnect()?;
            Ok(())
        }));
    }
    for h in handles {
        h.join().unwrap()?;
    }

    // sequential consumer: the canonical image is a plain row-major dump
    let mut c = pool.client()?;
    let h = c.open("A.dat", OpenMode::rdonly())?;
    let mut buf = vec![0u8; (N * N * 4) as usize];
    let n = c.read_at(h, 0, &mut buf)?;
    assert_eq!(n, buf.len());
    for i in 0..(N * N) as usize {
        let v = u32::from_le_bytes(buf[i * 4..][..4].try_into().unwrap());
        assert_eq!(v as usize, i, "canonical image broken at element {i}");
    }
    println!("sequential reader: canonical row-major image verified ({n} bytes)");

    // redistribution for free: re-read as (CYCLIC(1), BLOCK) on P(4,1) —
    // a completely different distribution, same file
    let b = ArrayDesc::new(&[N, N], &[Dist::Cyclic(1), Dist::Star], &[4, 1], 4)?;
    for rank in 0..4u32 {
        let mut c = pool.client()?;
        let h = c.open("A.dat", OpenMode::rdonly())?;
        let n = (b.local_elems(rank) * 4) as usize;
        let mut buf = vec![0u8; n];
        read_local(&mut c, h, &b, rank, 0, &mut buf)?;
        // rank owns rows rank, rank+4, ... — first element of row r is r*N
        let first = u32::from_le_bytes(buf[..4].try_into().unwrap());
        assert_eq!(first, rank * N);
        c.disconnect()?;
    }
    println!("re-read under (CYCLIC(1), *) ONTO P(4): redistribution served by views");

    pool.shutdown()?;
    println!("hpf_arrays OK");
    Ok(())
}
