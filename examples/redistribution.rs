//! Redistribution — the flexibility claim of Chapter 1: "it is possible
//! to read from a persistent file using a data distribution scheme
//! different than the one used when the file was written. This is not
//! directly supported by ROMIO."
//!
//! Four writers store a file BLOCK-distributed (each SPMD process its
//! contiguous quarter); four readers later consume it CYCLIC(16K) — a
//! different problem distribution. ViPIOS serves the new access pattern
//! server-side through views; the data never takes a detour through a
//! client-side repartitioning step.
//!
//! Run: `cargo run --release --example redistribution`

use std::sync::{Arc, Barrier};

use vipios::hints::{FileAdminHint, Hint};
use vipios::layout::Distribution;
use vipios::modes::ServerPool;
use vipios::msg::OpenMode;
use vipios::server::ServerConfig;
use vipios::vimpios::{get_view_pattern, Basic, Datatype};

const NPROCS: usize = 4;
const TOTAL: u64 = 8 << 20; // 8 MiB

fn main() -> anyhow::Result<()> {
    let pool = ServerPool::start(4, ServerConfig::default())?;

    // preparation phase: physical layout = BLOCK over 4 servers, matching
    // the writers' SPMD distribution (static fit)
    {
        let mut c = pool.client()?;
        c.hint(Hint::FileAdmin(FileAdminHint {
            name: "redist.dat".into(),
            distribution: Distribution::block_for(TOTAL, 4),
            nprocs: Some(NPROCS as u32),
        }))?;
        c.disconnect()?;
    }

    // phase 1: four writers, BLOCK distribution (process p owns quarter p)
    let barrier = Arc::new(Barrier::new(NPROCS));
    let mut handles = Vec::new();
    for p in 0..NPROCS {
        let world = pool.world().clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let mut c = vipios::client::Client::connect(&world)?;
            let h = c.open("redist.dat", OpenMode::rdwr_create())?;
            let per = TOTAL / NPROCS as u64;
            // every byte records its writer id
            let data = vec![p as u8 + 1; per as usize];
            c.write_at(h, p as u64 * per, &data)?;
            c.sync(h)?;
            barrier.wait();
            c.disconnect()?;
            Ok(())
        }));
    }
    for h in handles {
        h.join().unwrap()?;
    }
    println!("wrote {} BLOCK-distributed by {NPROCS} writers", TOTAL);

    // phase 2: four readers with a CYCLIC(16K) view — a different
    // distribution than written
    let k: u32 = 16 * 1024;
    let mut handles = Vec::new();
    for p in 0..NPROCS {
        let world = pool.world().clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<(u64, [u64; NPROCS])> {
            let mut c = vipios::client::Client::connect(&world)?;
            let h = c.open("redist.dat", OpenMode::rdonly())?;
            let dt = Datatype::darray_cyclic1(
                (TOTAL / 4) as u32,
                k / 4,
                p as u32,
                NPROCS as u32,
                Datatype::Basic(Basic::Int),
            )?;
            c.set_view(h, 0, get_view_pattern(&dt))?;
            let mut buf = vec![0u8; 1 << 20];
            let mut got = 0u64;
            let mut per_writer = [0u64; NPROCS];
            loop {
                let n = c.read(h, &mut buf)?;
                for &b in &buf[..n] {
                    if b >= 1 && b as usize <= NPROCS {
                        per_writer[b as usize - 1] += 1;
                    }
                }
                got += n as u64;
                if n < buf.len() {
                    break;
                }
            }
            c.disconnect()?;
            Ok((got, per_writer))
        }));
    }
    let mut total = 0u64;
    for (p, h) in handles.into_iter().enumerate() {
        let (got, per_writer) = h.join().unwrap()?;
        println!(
            "reader {p}: {got} bytes via CYCLIC({k}) view, from writers {:?}",
            per_writer
        );
        // with BLOCK size 2 MiB and CYCLIC 16 KiB, every reader sees all
        // four writers' data — the redistribution actually happened
        assert!(per_writer.iter().all(|&n| n > 0), "reader {p} missed a writer");
        assert_eq!(got, TOTAL / NPROCS as u64);
        total += got;
    }
    assert_eq!(total, TOTAL);
    println!("redistribution OK: BLOCK-written file consumed CYCLIC with no rewrite");
    pool.shutdown()?;
    Ok(())
}
