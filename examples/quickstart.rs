//! Quickstart: the paper's Appendix A.2 example, modernised — start an
//! independent-mode server pool, connect, write/read a file through the
//! `Vipios_*` interface, use a hint, inspect server stats.
//!
//! Run: `cargo run --release --example quickstart`

use vipios::hints::{FileAdminHint, Hint};
use vipios::layout::Distribution;
use vipios::modes::ServerPool;
use vipios::msg::OpenMode;
use vipios::server::ServerConfig;

fn main() -> anyhow::Result<()> {
    // 1. start four ViPIOS servers (independent mode: they run until
    //    shutdown; clients come and go)
    let pool = ServerPool::start(4, ServerConfig::default())?;
    println!("started {} ViPIOS servers", pool.server_ranks().len());

    // 2. preparation phase: tell ViPIOS how the file will be used
    //    (normally the HPF compiler emits this hint)
    let mut c = pool.client()?;
    println!("connected; buddy server = {:?}", c.buddy());
    c.hint(Hint::FileAdmin(FileAdminHint {
        name: "quickstart.dat".into(),
        distribution: Distribution::Cyclic { chunk: 4096 },
        nprocs: Some(1),
    }))?;

    // 3. write a megabyte, scattered over all four servers
    let h = c.open("quickstart.dat", OpenMode::rdwr_create())?;
    let data: Vec<u8> = (0..1 << 20).map(|i| (i % 251) as u8).collect();
    let written = c.write(h, &data)?;
    println!("wrote {written} bytes (cyclic over 4 servers)");

    // 4. read a slice back through an explicit offset
    let mut buf = vec![0u8; 4096];
    c.read_at(h, 512 * 1024, &mut buf)?;
    assert_eq!(buf[..8], data[512 * 1024..512 * 1024 + 8]);
    println!("read back 4 KiB at offset 512 KiB: OK");

    // 5. asynchronous I/O (Vipios_IRead): overlap two reads
    let op1 = c.iread_at(h, 0, 65536)?;
    let op2 = c.iread_at(h, 65536, 65536)?;
    let r1 = c.wait(op1)?;
    let r2 = c.wait(op2)?;
    if let (vipios::client::OpResult::Read(a), vipios::client::OpResult::Read(b)) = (r1, r2) {
        assert_eq!(a.len() + b.len(), 131072);
        println!("two overlapped IReads completed: {} bytes", a.len() + b.len());
    }

    // 6. per-server statistics (admin interface)
    for &s in pool.server_ranks() {
        let st = c.stats_of(s)?;
        println!(
            "  server {:?}: {} ext reqs, {} int reqs, {} B read, {} B written",
            s, st.ext_requests, st.int_requests, st.bytes_read, st.bytes_written
        );
    }

    c.close(h)?;
    c.disconnect()?;
    pool.shutdown()?;
    println!("done");
    Ok(())
}
