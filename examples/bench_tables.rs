//! Regenerate every Chapter-8 table/figure (experiment index DESIGN.md
//! §5) in quick mode. `cargo bench` runs the full-size versions.
//!
//! Run: `cargo run --release --example bench_tables [exp]`

fn main() -> anyhow::Result<()> {
    let exp = std::env::args().nth(1).unwrap_or_else(|| "all".into());
    vipios::bench::tables::run(&exp, true)
}
