//! End-to-end driver (DESIGN.md E8): out-of-core Jacobi iteration on a
//! 2048x2048 f32 array stored in ViPIOS across 4 servers, with the block
//! kernel executed through the runtime's compute backend — the pure-Rust
//! reference interpreter on the default feature set, or the AOT-compiled
//! Pallas/JAX artifact (`jacobi_step.hlo.txt`) on the PJRT CPU client
//! when built with `--features xla` after `make artifacts`.
//!
//! This proves the layers compose: the L3 rust coordinator (ViPIOS
//! servers + VI) moves blocks and the backend executes the L2/L1 kernel
//! semantics, with Python nowhere on the path. The residual
//! sum-of-squares is the convergence metric (it must decrease
//! monotonically for Jacobi on a zero-BC problem).
//!
//! Run: `cargo run --release --example ooc_stencil [sweeps] [nb]`

use std::time::Instant;

use vipios::modes::ServerPool;
use vipios::ooc::{jacobi_sweep, BlockedArray};
use vipios::runtime::{Runtime, Tensor, BLOCK};
use vipios::server::ServerConfig;
use vipios::util::{fmt_bytes, mbps};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sweeps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let nb: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let edge = nb * BLOCK;
    let bytes = (edge * edge * 4) as u64;
    println!(
        "OOC Jacobi: {edge}x{edge} f32 ({}), {nb}x{nb} blocks of {BLOCK}^2, {sweeps} sweeps",
        fmt_bytes(bytes)
    );

    // L3: ViPIOS pool + client
    let pool = ServerPool::start(4, ServerConfig::default())?;
    let mut c = pool.client()?;

    // runtime: load the kernel once (repo-root artifacts/ under the
    // `xla` feature — where `make artifacts` writes; reference backend
    // otherwise)
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");
    let mut rt = Runtime::new(artifacts)?;
    rt.load("jacobi_step")?;
    println!("compute platform: {}", rt.platform());

    // initialise: hot square in the centre of the array
    let src = BlockedArray::create(&mut c, "jacobi_src", nb)?;
    let dst = BlockedArray::create(&mut c, "jacobi_dst", nb)?;
    let t_init = Instant::now();
    for bi in 0..nb {
        for bj in 0..nb {
            let mut t = Tensor::zeros(vec![BLOCK, BLOCK]);
            // hot region: central quarter of the array
            for r in 0..BLOCK {
                for col in 0..BLOCK {
                    let gr = bi * BLOCK + r;
                    let gc = bj * BLOCK + col;
                    if (edge / 4..3 * edge / 4).contains(&gr)
                        && (edge / 4..3 * edge / 4).contains(&gc)
                    {
                        t.data[r * BLOCK + col] = 100.0;
                    }
                }
            }
            src.write_block(&mut c, bi, bj, &t)?;
        }
    }
    println!(
        "init: wrote {} in {:.2}s",
        fmt_bytes(bytes),
        t_init.elapsed().as_secs_f64()
    );

    // sweep loop with array-level double buffering (src <-> dst)
    let (mut a, mut b) = (src, dst);
    let mut last_res = f64::INFINITY;
    for s in 0..sweeps {
        let t0 = Instant::now();
        let stats = jacobi_sweep(&mut c, &mut rt, &a, &b, true)?;
        let el = t0.elapsed();
        let io_bytes = stats.bytes_read + stats.bytes_written;
        println!(
            "sweep {s}: residual={:.3e}  {} blocks  io={}  {:.1} MB/s  {:.2}s",
            stats.residual_sumsq,
            stats.blocks,
            fmt_bytes(io_bytes),
            mbps(io_bytes, el),
            el.as_secs_f64()
        );
        assert!(
            stats.residual_sumsq <= last_res,
            "Jacobi residual must not increase"
        );
        last_res = stats.residual_sumsq;
        std::mem::swap(&mut a, &mut b);
    }

    // integrity: total heat is conserved in the interior (minus boundary
    // leakage) — checksum via the block_reduce artifact
    let mut total = 0f64;
    for bi in 0..nb {
        for bj in 0..nb {
            let t = a.read_block(&mut c, bi, bj)?;
            let out = rt.run("block_reduce", &[t])?;
            total += out[0].data[0] as f64;
        }
    }
    println!("final field sum = {total:.3e} (diffused from 1.0e+02 x {} cells)",
        (edge / 2) * (edge / 2));

    c.disconnect()?;
    pool.shutdown()?;
    println!("ooc_stencil OK");
    Ok(())
}
