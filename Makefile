# ViPIOS reproduction — build/test entry points.
#
# The Rust crate is hermetic: `make test` needs no Python, no XLA and no
# network (the default build interprets the compute kernels with the
# pure-Rust reference backend, see rust/src/runtime.rs).
#
# `make artifacts` AOT-lowers the JAX/Pallas kernels to HLO text for the
# optional PJRT backend (`cargo build --features xla`). It needs the Python
# toolchain (jax) and is a no-op when the inputs are unchanged.

PYTHON ?= python3
KERNELS := stencil5 jacobi_step matmul_tile block_reduce
ARTIFACTS := $(KERNELS:%=artifacts/%.hlo.txt)
PY_SOURCES := python/compile/aot.py python/compile/model.py \
              $(wildcard python/compile/kernels/*.py)

.PHONY: all build test bench artifacts pytest clean

all: build

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

bench:
	cd rust && cargo bench -- all --quick

# AOT artifacts for the `xla` feature (no-op when inputs are unchanged).
artifacts: $(ARTIFACTS)

artifacts/%.hlo.txt: $(PY_SOURCES)
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts --only $*

pytest:
	cd python && $(PYTHON) -m pytest -q

clean:
	rm -rf rust/target target artifacts
